//! The `OWQ1` quantised-artifact store: a durable, self-describing
//! container for entropy-coded quantised tensors, and the serving-side
//! reader that streams them back through the fused decode kernels.
//!
//! This is where the in-memory pipeline (`eval::pipeline`) meets disk:
//! [`writer::pack_store`] runs the *same* quantiser construction, outlier
//! selection and fused encode as the in-memory qdq path (via
//! [`crate::eval::pipeline::encode_tensor`]) and persists the result;
//! [`Artifact`] decodes any tensor lazily, bit-identical to what
//! `qdq_tensor` would have produced; [`server::ArtifactServer`] wraps the
//! reader for concurrent serving with an LRU decoded-tensor cache,
//! single-flight decode coalescing and a corruption quarantine.
//!
//! # Byte layout (also documented in `EXPERIMENTS.md` §Artifact)
//!
//! ```text
//! [0..4)            magic  b"OWQ1"
//! [4..8)            manifest byte length M, u32 LE
//! [8..8+M)          manifest, UTF-8 JSON
//! [8+M..8+M+8)      FNV-1a 64 of the manifest bytes, u64 LE
//! [8+M+8..)         payload: per-tensor sections, each 64-byte aligned
//!                   relative to the payload base, offsets in the manifest
//! ```
//!
//! Per tensor the manifest records name/shape/channel-axis, the resolved
//! scheme spec, layout (`channel_len`, `transposed`), the resolved scale
//! multiplier, storage bits, honest bits accounting and the pipeline
//! sq-err (all four f64s as 16-hex-digit bit patterns — exact, no decimal
//! round-trip in the loop), plus six sections:
//!
//! | section       | contents                                  |
//! |---------------|-------------------------------------------|
//! | `codebook`    | sorted codepoints, f32 LE (grid: dense-slot codepoint table, first-occurrence order) |
//! | `scales`      | per-group scales, f32 LE (grid: empty)    |
//! | `payload`     | indices: raw u16 LE, or a K-lane interleaved Huffman/rANS container |
//! | `counts`      | index histogram, u64 LE (the entropy model the payload was coded under) |
//! | `outlier_idx` | sorted outlier positions (layout space), u32 LE (grid: empty) |
//! | `outlier_val` | exact outlier values, f32 LE (grid: empty) |
//!
//! # Container version 2 (OWQ2)
//!
//! Version 2 (same magic; the manifest `version` field is the rev) makes
//! every sweep-grammar scheme servable.  Two optional per-tensor manifest
//! fields carry the durable forms:
//!
//! * `rot_seed` (hex u64) — present iff the tensor was actually rotated
//!   (`:rot` scheme *and* 2-D shape).  Rotations are deterministic, so
//!   nothing else is persisted: the reader re-derives V/W through
//!   [`crate::eval::pipeline::rotation_pair`] and applies
//!   `rotate_2d_inverse` after the fused dequant.  A `:rot` scheme on a
//!   non-2-D tensor is a documented identity — the field is absent and
//!   both paths agree explicitly that no basis change was applied.
//! * `grid` (`{delta, buckets}`) — for codebook-free `grid` schemes:
//!   hex-exact δ plus the dense-slot → raw-bucket map.  The codebook
//!   section holds the dense-slot codepoint table (`points[s] =
//!   dequantise(buckets[s])`, cross-checked against δ at decode), the
//!   payload section the entropy-coded dense stream, and decode is a
//!   direct gather — never through `Codebook`, which would sort the
//!   first-occurrence-ordered slots.
//!
//! Version-1 containers (which never packed rot/grid tensors) parse with
//! both fields absent and decode unchanged; readers accept
//! `MIN_VERSION..=VERSION`.
//!
//! # Container version 3 (OWQ3)
//!
//! Version 3 (same byte layout again; manifest-only rev, per the OWQ2
//! pattern) carries the fractional-bit allocator's output
//! ([`crate::alloc::frac`]): a tensor may be a block-level *mix* of two
//! (in general, up to [`MAX_MIX_PARTS`]) codebook schemes whose
//! element-weighted rate hits a budget between the integer lattice
//! points.  Two optional per-tensor fields appear together or not at
//! all:
//!
//! * `mix` (manifest object, [`MixRecord`]) — the scheme-id table: one
//!   full spec string per part plus hex-exact per-part multipliers and
//!   codebook storage bits, and the per-part lengths that split the
//!   shared sections (`points_len`, `payload_len`, `part_elems`).
//! * `block_schemes` (payload section, u8 per scale block) — the
//!   per-block scheme-id stream, through the same checksummed-section
//!   machinery as every other section (64-byte aligned, FNV-1a
//!   verified, so a single flipped id bit is always detected).
//!
//! A mixed tensor's six regular sections hold the per-part
//! concatenations in part order: codebook = concatenated codepoint
//! tables, scales = concatenated per-group scales (group structure
//! re-derived from the id stream), payload = concatenated entropy
//! streams (each part coded with its own table), counts = concatenated
//! histograms; outlier sections are empty (mixing rejects `:sparse`).
//! Decode gathers each part's blocks, runs the same fused kernels a
//! pure tensor uses, and scatters back — bit-identical to
//! [`crate::eval::pipeline::encode_tensor_mixed`]'s reconstruction.
//! The top-level `multiplier`/`storage_bits` of a mixed record are NaN
//! (unused; the real values are per part), and its `spec` records the
//! base scheme with the realised fractional bit width, so v2-era
//! tooling that only reads specs still reports honest rates.
//!
//! Version 1/2 containers parse with both fields absent; readers accept
//! `MIN_VERSION..=VERSION` (1..=3).
//!
//! # Fault model (see `EXPERIMENTS.md` §Fault-model)
//!
//! Every failure is a typed [`ArtifactError`], not a string.  Container
//! bytes come through a [`ByteSource`] so the same reader serves the
//! production pread path ([`Artifact::open`] — positioned per-section
//! reads at the recorded offsets, never a whole-file image), pristine
//! memory and the fault-injecting [`crate::util::faultfs::FaultFs`];
//! transient read errors retry with bounded exponential backoff through an
//! injectable [`retry::Clock`] — corruption never retries.
//!
//! Detection is layered.  Truncation and bad magic fail at
//! [`Artifact::open`] as [`ArtifactError::TornContainer`] (section bounds
//! are validated eagerly against the source length).  Flipped bits fail at
//! first decode of the affected tensor as [`ArtifactError::Corrupt`]
//! naming the tensor and section: FNV-1a's per-byte step `h = (h ^ b) * p`
//! is a bijection of the running state, so *any* single corrupted byte in
//! a checksummed range is guaranteed to change the digest — there is no
//! single-bit-flip that slips through ([`Artifact::verify_all`] forces
//! every checksum eagerly; `owf fsck` builds on it).  Checksums verify
//! before entropy decoding, so the panicking coder paths normally only see
//! writer-produced bytes; as defence in depth the whole decode runs under
//! `catch_unwind`, converting any decoder panic into a typed `Corrupt` at
//! the artifact boundary, and the rANS path additionally verifies the
//! final decoder state (`rans_decode_interleaved_checked`) so trailing
//! damage cannot yield silently wrong indices.  The guarantee enforced by
//! `rust/tests/fault_props.rs`: any single-bit flip anywhere in a
//! container yields a typed error or bit-exact output — never a panic,
//! never silent wrong data.

pub mod error;
pub mod queue;
pub mod retry;
pub mod server;
pub mod writer;

pub use error::ArtifactError;
pub use retry::{Clock, Deadline, RetryPolicy, SystemClock};

use std::borrow::Cow;
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context};

use crate::coordinator::config::{Element, Scheme};
use crate::quant::{Encoded, Quantiser};
use crate::scaling::scale_groups;
use crate::util::faultfs::ByteSource;
use crate::util::json::Json;

/// Shorthand for artifact-layer results (typed errors only).
pub type AResult<T> = std::result::Result<T, ArtifactError>;

pub const MAGIC: &[u8; 4] = b"OWQ1";
/// Current container rev written by the packer (see module docs: v2 adds
/// the `rot_seed` / `grid` manifest records, v3 the `mix` /
/// `block_schemes` fractional-allocation records).
pub const VERSION: usize = 3;
/// Oldest container rev the reader still accepts.
pub const MIN_VERSION: usize = 1;
/// Most schemes one mixed tensor may combine.  The water-filling
/// allocator emits at most two, but the container format (u8 scheme ids,
/// per-part length vectors) is validated against this bound so the
/// grammar has room without another rev.
pub const MAX_MIX_PARTS: usize = 8;
/// Section alignment within the payload region (matches `.owt`).
pub const ALIGN: usize = 64;

/// FNV-1a 64-bit — the container checksum (from scratch; no external
/// crates offline).  Not cryptographic: it detects torn writes and bit
/// rot, which is the failure model for a local artifact store.  Each step
/// `h = (h ^ b) * prime` is a bijection of `h` (odd multiplier mod 2^64),
/// so two inputs differing in exactly one byte can never collide — the
/// single-bit-flip detection guarantee the fault suite leans on.
/// Dispatches on the active ISA: a forced-scalar pin runs the byte-serial
/// oracle, everything else the word-at-a-time loads — bit-identical by
/// construction ([`crate::util::simd`] module docs; the forced-ISA tests
/// prove it per length), so checksums never depend on the path taken.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    crate::util::simd::fnv1a64_with(crate::util::simd::active(), bytes)
}

/// Exact f64 interchange: 16 hex digits of the IEEE bit pattern.  Used for
/// the multiplier / storage-bits / bits / sq-err manifest fields so
/// "bit-identical to the in-memory pipeline" survives serialisation
/// (and NaN/∞ never hit the JSON number grammar).
pub fn f64_to_hex(x: f64) -> String {
    format!("{:016x}", x.to_bits())
}

pub fn f64_from_hex(s: &str) -> anyhow::Result<f64> {
    anyhow::ensure!(s.len() == 16, "bad f64 hex field {s:?}");
    let bits = u64::from_str_radix(s, 16)
        .with_context(|| format!("bad f64 hex field {s:?}"))?;
    Ok(f64::from_bits(bits))
}

pub fn u64_to_hex(x: u64) -> String {
    format!("{x:016x}")
}

pub fn u64_from_hex(s: &str) -> anyhow::Result<u64> {
    anyhow::ensure!(s.len() == 16, "bad u64 hex field {s:?}");
    u64::from_str_radix(s, 16)
        .with_context(|| format!("bad u64 hex field {s:?}"))
}

/// Index-payload codec of a container (one per artifact).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Codec {
    /// Indices stored verbatim as u16 LE (no entropy coding).
    Raw,
    /// K-lane interleaved canonical Huffman
    /// ([`crate::compress::huffman::HuffmanCode::encode_interleaved`]).
    Huffman,
    /// K interleaved rANS states over one shared stream
    /// ([`crate::compress::rans::rans_encode_interleaved`]).
    Rans,
}

impl Codec {
    pub fn name(&self) -> &'static str {
        match self {
            Codec::Raw => "raw",
            Codec::Huffman => "huffman",
            Codec::Rans => "rans",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Codec> {
        match s {
            "raw" => Ok(Codec::Raw),
            "huffman" => Ok(Codec::Huffman),
            "rans" => Ok(Codec::Rans),
            other => bail!("unknown codec {other:?} (raw|huffman|rans)"),
        }
    }
}

/// One checksummed byte range in the payload region.
#[derive(Clone, Copy, Debug)]
pub struct Section {
    pub off: usize,
    pub len: usize,
    pub fnv: u64,
}

/// The durable form of one `grid`-scheme tensor (v2 manifests): the
/// hex-exact resolution plus the dense-slot → raw-bucket map.  The
/// codepoint table in the codebook section is redundant with these two
/// (`points[s] = UniformGrid::new(delta).dequantise(buckets[s])`) and the
/// reader cross-checks it bit-for-bit before gathering.
#[derive(Clone, Debug)]
pub struct GridRecord {
    pub delta: f64,
    /// Dense slot → raw grid bucket, first-occurrence order.
    pub buckets: Vec<u16>,
}

/// The per-part metadata of a mixed (fractional-allocation) tensor — v3
/// manifests.  The per-block scheme-id stream itself lives in the
/// checksummed `block_schemes` section; everything here is cross-checked
/// against that stream at decode (part element counts, section splits),
/// so a manifest/stream disagreement surfaces typed instead of decoding
/// garbage.
#[derive(Clone, Debug)]
pub struct MixRecord {
    /// Scheme-id table: part id → full spec string (parses through
    /// [`Scheme::parse`]; all parts share the base granularity and
    /// flags, only the bit width differs).
    pub specs: Vec<String>,
    /// Resolved per-part scale multipliers (hex-exact).
    pub multipliers: Vec<f64>,
    /// Per-part codebook storage bits (hex-exact).
    pub storage_bits: Vec<f64>,
    /// Codepoints per part in the concatenated codebook/counts sections.
    pub points_len: Vec<usize>,
    /// Coded bytes per part in the concatenated payload section.
    pub payload_len: Vec<usize>,
    /// Elements per part — redundant with the id stream, cross-checked.
    pub part_elems: Vec<usize>,
}

/// Manifest record of one packed tensor.
#[derive(Clone, Debug)]
pub struct TensorRecord {
    pub name: String,
    pub shape: Vec<usize>,
    pub channel_axis: Option<usize>,
    /// Resolved per-tensor scheme spec (bits may differ from the pack spec
    /// under variable allocation); parses back through [`Scheme::parse`].
    pub spec: String,
    pub n: usize,
    /// Resolved scale multiplier (search already performed at pack time).
    pub multiplier: f64,
    pub storage_bits: f64,
    /// Layout channel-group length (0 for non-channel granularities).
    pub channel_len: usize,
    pub transposed: bool,
    /// Honest bits/element, same accounting as the in-memory pipeline.
    pub bits: f64,
    /// Pipeline sq-err vs the source tensor, bit-exact.
    pub sq_err: f64,
    /// Rotation seed — present iff the tensor was actually rotated
    /// (`:rot` scheme and 2-D shape); absent on v1 manifests, which never
    /// packed rotated tensors.
    pub rot_seed: Option<u64>,
    /// Grid durable form — present iff the scheme element is `grid`;
    /// absent on v1 manifests.
    pub grid: Option<GridRecord>,
    /// Fractional-mix durable form — present iff the tensor is a
    /// block-level scheme mix (v3 manifests); always paired with a
    /// `block_schemes` section.
    pub mix: Option<MixRecord>,
    pub codebook: Section,
    pub scales: Section,
    pub payload: Section,
    pub counts: Section,
    pub outlier_idx: Section,
    pub outlier_val: Section,
    /// Per-block scheme-id stream (u8 per scale block) — present iff
    /// `mix` is.
    pub block_schemes: Option<Section>,
}

impl TensorRecord {
    pub fn numel(&self) -> usize {
        self.n
    }

    /// The named sections, in container order (fsck, fault injection
    /// and the flip-sweep tests walk these to map offsets to owners):
    /// the six every tensor has, plus `block_schemes` on mixed tensors.
    pub fn sections(&self) -> Vec<(&'static str, &Section)> {
        let mut v = vec![
            ("codebook", &self.codebook),
            ("scales", &self.scales),
            ("payload", &self.payload),
            ("counts", &self.counts),
            ("outlier_idx", &self.outlier_idx),
            ("outlier_val", &self.outlier_val),
        ];
        if let Some(s) = &self.block_schemes {
            v.push(("block_schemes", s));
        }
        v
    }
}

/// The bit-allocation record carried in the manifest (eq. 5 / fig. 6).
#[derive(Clone, Debug)]
pub struct AllocRecord {
    /// "flat" or "variable".
    pub scheme: String,
    pub target: f64,
    pub average: f64,
    /// Per-tensor bit widths, same order as `tensors`.
    pub bits: Vec<f64>,
}

/// A parsed OWQ container: manifest + byte source, with lazy,
/// checksum-verified, panic-contained per-tensor decoding.
pub struct Artifact {
    pub meta: Json,
    /// Manifest container rev (`MIN_VERSION..=VERSION`).
    pub version: usize,
    pub codec: Codec,
    pub lanes: usize,
    pub alloc: Option<AllocRecord>,
    /// Store tensors the packer skipped (non-f32 or empty) — empty for
    /// v1 manifests, which did not record them.
    pub skipped: Vec<String>,
    pub tensors: Vec<TensorRecord>,
    index: HashMap<String, usize>,
    source: ByteSource,
    /// Absolute file offset where the payload region begins.
    payload_base: usize,
    retry: RetryPolicy,
    clock: Arc<dyn Clock>,
    io_retries: AtomicU64,
}

fn invalid(e: impl std::fmt::Display) -> ArtifactError {
    ArtifactError::invalid(e)
}

/// Back into the original basis: V/W re-derived from the recorded seed
/// through the one shared helper, inverse applied after the fused
/// dequant — exactly where `qdq_tensor` applies it.  Shared by the plain
/// and mixed decode paths (a no-op without a rotation record).
fn apply_inverse_rotation(rec: &TensorRecord, out: &mut [f32]) {
    if let Some(seed) = rec.rot_seed {
        let (rows, cols) = (rec.shape[0], rec.shape[1]);
        let (v, w) = crate::eval::pipeline::rotation_pair(rows, cols, seed);
        crate::quant::rotation::rotate_2d_inverse(out, rows, cols, &v, &w);
    }
}

fn req(j: &Json, key: &str) -> AResult<Json> {
    Ok(j.req(key).map_err(invalid)?.clone())
}

fn req_str(j: &Json, key: &str) -> AResult<String> {
    Ok(j.req_str(key).map_err(invalid)?.to_string())
}

fn req_usize(j: &Json, key: &str) -> AResult<usize> {
    j.req_usize(key).map_err(invalid)
}

fn req_hex_f64(j: &Json, key: &str) -> AResult<f64> {
    f64_from_hex(&req_str(j, key)?)
        .map_err(|e| invalid(format!("field {key:?}: {e}")))
}

fn section_from(j: &Json, key: &str) -> AResult<Section> {
    let s = j
        .get("sections")
        .and_then(|s| s.get(key))
        .ok_or_else(|| invalid(format!("missing section {key:?}")))?;
    Ok(Section {
        off: req_usize(s, "off")?,
        len: req_usize(s, "len")?,
        fnv: u64_from_hex(&req_str(s, "fnv")?)
            .map_err(|e| invalid(format!("section {key:?}: {e}")))?,
    })
}

/// Read with bounded retry on transient faults.  `UnexpectedEof` means the
/// container is shorter than its metadata promises — torn, not retryable.
fn read_retry<'a>(
    source: &'a ByteSource,
    off: usize,
    len: usize,
    what: &str,
    policy: &RetryPolicy,
    clock: &dyn Clock,
    retries: &AtomicU64,
) -> AResult<Cow<'a, [u8]>> {
    retry::with_retry(policy, clock, retries, || {
        source.read_at(off, len).map_err(|e| {
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                ArtifactError::torn(format!("{what}: {e}"))
            } else {
                ArtifactError::io(&e, what)
            }
        })
    })
}

/// Best-effort panic payload message (mirrors `util::testing`).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<String>()
        .map(|s| s.as_str())
        .or_else(|| payload.downcast_ref::<&str>().copied())
        .unwrap_or("<non-string panic>")
}

impl Artifact {
    /// Open a container file for pread-style serving: the header and
    /// manifest are read eagerly, then every per-tensor section is read
    /// at its recorded offset on demand (`ByteSource::File` issues
    /// positioned reads on a shared descriptor — no whole-file image is
    /// ever materialised, so a large store costs open-time metadata I/O
    /// only and concurrent decoders never contend on a buffer).
    pub fn open(path: impl AsRef<Path>) -> AResult<Artifact> {
        let path = path.as_ref();
        let source = ByteSource::open_file(path)
            .map_err(|e| ArtifactError::io(&e, format!("open {path:?}")))?;
        Artifact::from_source(source)
    }

    /// Parse a container from raw in-memory bytes (zero-copy reads).
    pub fn from_bytes(raw: Vec<u8>) -> AResult<Artifact> {
        Artifact::from_source(ByteSource::Mem(raw))
    }

    /// Parse a container from any byte source with the default retry
    /// policy and the system clock.
    pub fn from_source(source: ByteSource) -> AResult<Artifact> {
        Artifact::from_source_with(
            source,
            RetryPolicy::default(),
            Arc::new(SystemClock),
        )
    }

    /// Parse a container from any byte source.  Structural problems — bad
    /// magic, torn manifest, sections out of range — error here as
    /// [`ArtifactError::TornContainer`]; a manifest checksum mismatch is
    /// [`ArtifactError::Corrupt`] on section `manifest`; payload
    /// corruption is caught at first decode of the affected tensor.
    /// Transient source faults retry under `policy`, sleeping via `clock`.
    pub fn from_source_with(
        source: ByteSource,
        policy: RetryPolicy,
        clock: Arc<dyn Clock>,
    ) -> AResult<Artifact> {
        let io_retries = AtomicU64::new(0);
        let read = |off: usize, len: usize, what: &str| {
            read_retry(
                &source, off, len, what, &policy, &*clock, &io_retries,
            )
            .map(Cow::into_owned)
        };
        let head = read(0, 8, "header")?;
        if &head[..4] != MAGIC {
            return Err(ArtifactError::torn("not an OWQ1 container"));
        }
        let mlen =
            u32::from_le_bytes([head[4], head[5], head[6], head[7]]) as usize;
        let base = 8 + mlen + 8;
        let mbytes = read(8, mlen + 8, "manifest")?;
        let manifest_bytes = &mbytes[..mlen];
        let want =
            u64::from_le_bytes(mbytes[mlen..].try_into().unwrap());
        if fnv1a64(manifest_bytes) != want {
            return Err(ArtifactError::corrupt(
                "",
                "manifest",
                "manifest checksum mismatch (corrupt or torn container)",
            ));
        }
        // Past the checksum, manifest problems are writer bugs or version
        // skew, not media damage — they classify as Invalid.
        let text = std::str::from_utf8(manifest_bytes)
            .map_err(|e| invalid(format!("manifest not utf-8: {e}")))?;
        let manifest = Json::parse(text)
            .map_err(|e| invalid(format!("manifest parse: {e}")))?;
        let version = req_usize(&manifest, "version")?;
        if !(MIN_VERSION..=VERSION).contains(&version) {
            return Err(invalid(format!(
                "unsupported OWQ version {version} \
                 (supported {MIN_VERSION}..={VERSION})"
            )));
        }
        let codec =
            Codec::parse(&req_str(&manifest, "codec")?).map_err(invalid)?;
        let lanes = req_usize(&manifest, "lanes")?;
        if !(1..=crate::compress::MAX_LANES).contains(&lanes) {
            return Err(invalid(format!("lane count {lanes} out of range")));
        }
        let meta = manifest.get("meta").cloned().unwrap_or(Json::obj());
        let skipped: Vec<String> = match manifest.get("skipped") {
            None | Some(Json::Null) => Vec::new(),
            Some(s) => s
                .as_arr()
                .ok_or_else(|| invalid("skipped not an array"))?
                .iter()
                .map(|j| {
                    j.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| invalid("bad skipped entry"))
                })
                .collect::<AResult<_>>()?,
        };
        let payload_len = source.len().saturating_sub(base);

        let mut tensors: Vec<TensorRecord> = Vec::new();
        let mut index = HashMap::new();
        let entries = req(&manifest, "tensors")?;
        let entries = entries
            .as_arr()
            .ok_or_else(|| invalid("tensors not an array"))?;
        for entry in entries {
            let name = req_str(entry, "name")?;
            let shape: Vec<usize> = req(entry, "shape")?
                .as_arr()
                .ok_or_else(|| invalid("shape not an array"))?
                .iter()
                .map(|j| {
                    j.as_usize().ok_or_else(|| invalid("bad shape entry"))
                })
                .collect::<AResult<_>>()?;
            let channel_axis = entry
                .get("channel_axis")
                .filter(|j| !j.is_null())
                .and_then(|j| j.as_usize());
            let rot_seed = match entry
                .get("rot_seed")
                .filter(|j| !j.is_null())
            {
                Some(j) => {
                    let s = j.as_str().ok_or_else(|| {
                        invalid(format!("{name}: rot_seed not a hex string"))
                    })?;
                    Some(u64_from_hex(s).map_err(invalid)?)
                }
                None => None,
            };
            let grid = match entry.get("grid").filter(|j| !j.is_null()) {
                Some(gj) => {
                    let buckets: Vec<u16> = req(gj, "buckets")?
                        .as_arr()
                        .ok_or_else(|| {
                            invalid(format!(
                                "{name}: grid buckets not an array"
                            ))
                        })?
                        .iter()
                        .map(|j| {
                            j.as_usize()
                                .filter(|&b| b <= u16::MAX as usize)
                                .map(|b| b as u16)
                                .ok_or_else(|| {
                                    invalid(format!(
                                        "{name}: grid bucket out of range"
                                    ))
                                })
                        })
                        .collect::<AResult<_>>()?;
                    Some(GridRecord {
                        delta: req_hex_f64(gj, "delta")?,
                        buckets,
                    })
                }
                None => None,
            };
            let mix = match entry.get("mix").filter(|j| !j.is_null()) {
                Some(mj) => {
                    let strings = |key: &str| -> AResult<Vec<String>> {
                        req(mj, key)?
                            .as_arr()
                            .ok_or_else(|| {
                                invalid(format!(
                                    "{name}: mix {key} not an array"
                                ))
                            })?
                            .iter()
                            .map(|j| {
                                j.as_str().map(str::to_string).ok_or_else(
                                    || {
                                        invalid(format!(
                                            "{name}: bad mix {key} entry"
                                        ))
                                    },
                                )
                            })
                            .collect()
                    };
                    let hexes = |key: &str| -> AResult<Vec<f64>> {
                        strings(key)?
                            .iter()
                            .map(|s| {
                                f64_from_hex(s).map_err(|e| {
                                    invalid(format!(
                                        "{name}: mix {key}: {e}"
                                    ))
                                })
                            })
                            .collect()
                    };
                    let usizes = |key: &str| -> AResult<Vec<usize>> {
                        req(mj, key)?
                            .as_arr()
                            .ok_or_else(|| {
                                invalid(format!(
                                    "{name}: mix {key} not an array"
                                ))
                            })?
                            .iter()
                            .map(|j| {
                                j.as_usize().ok_or_else(|| {
                                    invalid(format!(
                                        "{name}: bad mix {key} entry"
                                    ))
                                })
                            })
                            .collect()
                    };
                    Some(MixRecord {
                        specs: strings("specs")?,
                        multipliers: hexes("multipliers")?,
                        storage_bits: hexes("storage_bits")?,
                        points_len: usizes("points_len")?,
                        payload_len: usizes("payload_len")?,
                        part_elems: usizes("part_elems")?,
                    })
                }
                None => None,
            };
            let block_schemes = match entry
                .get("sections")
                .and_then(|s| s.get("block_schemes"))
            {
                Some(_) => Some(section_from(entry, "block_schemes")?),
                None => None,
            };
            let rec = TensorRecord {
                spec: req_str(entry, "spec")?,
                n: req_usize(entry, "n")?,
                multiplier: req_hex_f64(entry, "multiplier")?,
                storage_bits: req_hex_f64(entry, "storage_bits")?,
                channel_len: req_usize(entry, "channel_len")?,
                transposed: entry
                    .get("transposed")
                    .and_then(|j| j.as_bool())
                    .ok_or_else(|| invalid("missing transposed flag"))?,
                bits: req_hex_f64(entry, "bits")?,
                sq_err: req_hex_f64(entry, "sq_err")?,
                rot_seed,
                grid,
                mix,
                codebook: section_from(entry, "codebook")?,
                scales: section_from(entry, "scales")?,
                payload: section_from(entry, "payload")?,
                counts: section_from(entry, "counts")?,
                outlier_idx: section_from(entry, "outlier_idx")?,
                outlier_val: section_from(entry, "outlier_val")?,
                block_schemes,
                name: name.clone(),
                shape,
                channel_axis,
            };
            if rec.shape.iter().product::<usize>() != rec.n {
                return Err(invalid(format!("{name}: shape/numel mismatch")));
            }
            if rec.mix.is_some() != rec.block_schemes.is_some() {
                return Err(invalid(format!(
                    "{name}: mix record and block_schemes section must \
                     appear together"
                )));
            }
            if let Some(m) = &rec.mix {
                let parts = m.specs.len();
                if !(2..=MAX_MIX_PARTS).contains(&parts) {
                    return Err(invalid(format!(
                        "{name}: mix with {parts} parts \
                         (2..={MAX_MIX_PARTS} supported)"
                    )));
                }
                if [
                    m.multipliers.len(),
                    m.storage_bits.len(),
                    m.points_len.len(),
                    m.payload_len.len(),
                    m.part_elems.len(),
                ]
                .iter()
                .any(|&l| l != parts)
                {
                    return Err(invalid(format!(
                        "{name}: ragged mix record"
                    )));
                }
                if m.part_elems.iter().sum::<usize>() != rec.n {
                    return Err(invalid(format!(
                        "{name}: mix part elements cover {} of {} elements",
                        m.part_elems.iter().sum::<usize>(),
                        rec.n
                    )));
                }
                if rec.transposed {
                    return Err(invalid(format!(
                        "{name}: mixed tensors are block-granularity \
                         (never transposed)"
                    )));
                }
                if rec.grid.is_some() {
                    return Err(invalid(format!(
                        "{name}: grid and mix records are exclusive"
                    )));
                }
            }
            if rec.transposed && rec.shape.len() != 2 {
                return Err(invalid(format!(
                    "{name}: transposed layout requires a 2-D shape"
                )));
            }
            if rec.rot_seed.is_some() && rec.shape.len() != 2 {
                return Err(invalid(format!(
                    "{name}: rotation record requires a 2-D shape \
                     (other ranks are documented identities)"
                )));
            }
            for (sname, s) in rec.sections() {
                let fits = s
                    .off
                    .checked_add(s.len)
                    .is_some_and(|end| end <= payload_len);
                if !fits {
                    return Err(ArtifactError::torn(format!(
                        "{name}: section {sname} out of range \
                         (truncated or torn file)"
                    )));
                }
            }
            if index.insert(name, tensors.len()).is_some() {
                return Err(invalid(format!(
                    "duplicate tensor {:?}",
                    rec.name
                )));
            }
            tensors.push(rec);
        }
        let alloc = match manifest.get("alloc") {
            None | Some(Json::Null) => None,
            Some(a) => Some(AllocRecord {
                scheme: req_str(a, "scheme")?,
                target: req_hex_f64(a, "target")?,
                average: req_hex_f64(a, "average")?,
                bits: req(a, "bits")?
                    .as_arr()
                    .ok_or_else(|| invalid("alloc bits not an array"))?
                    .iter()
                    .map(|j| {
                        j.as_str()
                            .ok_or_else(|| invalid("alloc bit not hex"))
                            .and_then(|s| {
                                f64_from_hex(s).map_err(invalid)
                            })
                    })
                    .collect::<AResult<_>>()?,
            }),
        };
        if let Some(a) = &alloc {
            if a.bits.len() != tensors.len() {
                return Err(invalid(format!(
                    "alloc record covers {} of {} tensors",
                    a.bits.len(),
                    tensors.len()
                )));
            }
        }
        Ok(Artifact {
            meta,
            version,
            codec,
            lanes,
            alloc,
            skipped,
            tensors,
            index,
            source,
            payload_base: base,
            retry: policy,
            clock,
            io_retries,
        })
    }

    pub fn position(&self, name: &str) -> Option<usize> {
        self.index.get(name).copied()
    }

    pub fn names(&self) -> Vec<&str> {
        self.tensors.iter().map(|t| t.name.as_str()).collect()
    }

    pub fn total_elements(&self) -> usize {
        self.tensors.iter().map(|t| t.n).sum()
    }

    pub fn payload_bytes(&self) -> usize {
        self.source.len().saturating_sub(self.payload_base)
    }

    /// Absolute file offset of the payload region (header + manifest +
    /// manifest checksum precede it).  Fault injection tooling uses this
    /// to aim at specific sections.
    pub fn payload_base(&self) -> usize {
        self.payload_base
    }

    /// Absolute file byte range of one section of one tensor, for
    /// targeted fault injection (`owf fault-inject`).
    pub fn section_file_range(
        &self,
        tensor: &str,
        section: &str,
    ) -> Option<(usize, usize)> {
        let rec = &self.tensors[self.position(tensor)?];
        let (_, s) = rec
            .sections()
            .into_iter()
            .find(|(name, _)| *name == section)?;
        Some((self.payload_base + s.off, s.len))
    }

    /// Transient reads retried so far (across all section fetches).
    pub fn io_retries(&self) -> u64 {
        self.io_retries.load(Ordering::Relaxed)
    }

    /// The injected time source.  The serving layer shares it for its
    /// deadline and watchdog arithmetic, so tests drive retry backoffs,
    /// deadlines and breaker cooldowns from one virtual timeline.
    pub fn clock(&self) -> Arc<dyn Clock> {
        self.clock.clone()
    }

    /// Fetch one section with bounded retry and its checksum verified.
    fn section(
        &self,
        name: &str,
        owner: &str,
        s: &Section,
    ) -> AResult<Cow<'_, [u8]>> {
        let bytes = read_retry(
            &self.source,
            self.payload_base + s.off,
            s.len,
            name,
            &self.retry,
            &*self.clock,
            &self.io_retries,
        )?;
        if fnv1a64(&bytes) != s.fnv {
            return Err(ArtifactError::corrupt(
                owner,
                name,
                "checksum mismatch (corrupt container)",
            ));
        }
        Ok(bytes)
    }

    fn f32_section(
        &self,
        name: &str,
        owner: &str,
        s: &Section,
    ) -> AResult<Vec<f32>> {
        let bytes = self.section(name, owner, s)?;
        if bytes.len() % 4 != 0 {
            return Err(ArtifactError::corrupt(
                owner,
                name,
                "ragged section length",
            ));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn u64_section(
        &self,
        name: &str,
        owner: &str,
        s: &Section,
    ) -> AResult<Vec<u64>> {
        let bytes = self.section(name, owner, s)?;
        if bytes.len() % 8 != 0 {
            return Err(ArtifactError::corrupt(
                owner,
                name,
                "ragged section length",
            ));
        }
        Ok(bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn u32_section(
        &self,
        name: &str,
        owner: &str,
        s: &Section,
    ) -> AResult<Vec<u32>> {
        let bytes = self.section(name, owner, s)?;
        if bytes.len() % 4 != 0 {
            return Err(ArtifactError::corrupt(
                owner,
                name,
                "ragged section length",
            ));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Force every section checksum of tensor `i` (no decode).
    pub fn verify_tensor(&self, i: usize) -> AResult<()> {
        let rec = &self.tensors[i];
        for (sname, s) in rec.sections() {
            self.section(sname, &rec.name, s)?;
        }
        Ok(())
    }

    /// Checksum one named section of tensor `i` (`owf fsck` uses this for
    /// per-section verdicts; `None` if the section name is unknown).
    pub fn verify_section(
        &self,
        i: usize,
        section: &str,
    ) -> Option<AResult<()>> {
        let rec = &self.tensors[i];
        let (sname, s) =
            rec.sections().into_iter().find(|(n, _)| *n == section)?;
        Some(self.section(sname, &rec.name, s).map(|_| ()))
    }

    /// Force every section checksum (the eager complement of the lazy
    /// per-decode verification).
    pub fn verify_all(&self) -> AResult<()> {
        for i in 0..self.tensors.len() {
            self.verify_tensor(i)?;
        }
        Ok(())
    }

    /// Decode tensor `i` into a fresh buffer (original row-major layout).
    pub fn decode_tensor(&self, i: usize) -> AResult<Vec<f32>> {
        let mut out = vec![0f32; self.tensors[i].n];
        self.decode_tensor_into(i, &mut out)?;
        Ok(out)
    }

    /// Decode tensor `i` into a caller-owned buffer: checksum-verified
    /// section reads → entropy decode (table-driven interleaved Huffman /
    /// K-state rANS / raw) → fused [`Quantiser::decode_into`] (or a direct
    /// codepoint gather for `grid` tensors) → outlier scatter-back →
    /// layout restore → inverse rotation when a `rot_seed` is recorded.
    /// Bit-identical to the in-memory pipeline's reconstruction for the
    /// recorded spec and seed (enforced by `rust/tests/artifact_props.rs`
    /// and the `scripts/check.sh` gate).
    ///
    /// No panic escapes: the decode runs under `catch_unwind`, so damage
    /// that slipped past a checksum (or a decoder bug) surfaces as a typed
    /// [`ArtifactError::Corrupt`], never an abort of the serving thread.
    /// On error the buffer contents are unspecified.
    pub fn decode_tensor_into(
        &self,
        i: usize,
        out: &mut [f32],
    ) -> AResult<()> {
        let rec = &self.tensors[i];
        if out.len() != rec.n {
            return Err(invalid(format!(
                "{}: output buffer holds {} of {} elements",
                rec.name,
                out.len(),
                rec.n
            )));
        }
        if rec.n == 0 {
            return Ok(());
        }
        let guarded = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| self.decode_guarded(rec, out)),
        );
        match guarded {
            Ok(result) => result,
            Err(payload) => Err(ArtifactError::corrupt(
                &rec.name,
                "decode",
                format!(
                    "decoder panic contained at artifact boundary: {}",
                    panic_message(&*payload)
                ),
            )),
        }
    }

    fn decode_guarded(
        &self,
        rec: &TensorRecord,
        out: &mut [f32],
    ) -> AResult<()> {
        let name = &rec.name;
        let corrupt = |section: &str, detail: String| {
            ArtifactError::corrupt(name, section, detail)
        };
        let scheme = Scheme::parse(&rec.spec)
            .map_err(|e| invalid(format!("{name}: stored spec: {e}")))?;
        // spec/record consistency (writer invariants, so disagreement is
        // a forged or buggy manifest — Invalid, not media damage, which
        // the checksums already rule out)
        let rotated = scheme.rotate && rec.shape.len() == 2;
        if rotated && rec.rot_seed.is_none() {
            return Err(invalid(format!(
                "{name}: :rot scheme on a 2-D tensor without a rotation \
                 record"
            )));
        }
        if !rotated && rec.rot_seed.is_some() {
            return Err(invalid(format!(
                "{name}: rotation record on a tensor the scheme does not \
                 rotate"
            )));
        }
        let is_grid = scheme.element == Element::Grid;
        if is_grid != rec.grid.is_some() {
            return Err(invalid(format!(
                "{name}: grid record and scheme element disagree"
            )));
        }
        if rec.mix.is_some() {
            // fractional mix (v3): per-part decode + scatter, then the
            // same shared inverse-rotation tail as every other form
            self.decode_mixed(rec, &scheme, out)?;
            apply_inverse_rotation(rec, out);
            return Ok(());
        }
        let points = self.f32_section("codebook", name, &rec.codebook)?;
        if points.is_empty() {
            return Err(corrupt("codebook", "empty codebook".into()));
        }
        let counts = self.u64_section("counts", name, &rec.counts)?;
        if counts.len() != points.len() {
            return Err(corrupt(
                "counts",
                format!(
                    "histogram covers {} of {} codepoints",
                    counts.len(),
                    points.len()
                ),
            ));
        }
        if counts.iter().sum::<u64>() as usize != rec.n {
            return Err(corrupt(
                "counts",
                "index histogram does not cover the tensor".into(),
            ));
        }
        let scales = self.f32_section("scales", name, &rec.scales)?;
        let indices = self.decode_indices(rec, &counts)?;
        if indices.len() != rec.n {
            return Err(corrupt(
                "payload",
                format!("decoded {} of {} indices", indices.len(), rec.n),
            ));
        }

        let idx = self.u32_section("outlier_idx", name, &rec.outlier_idx)?;
        let val = self.f32_section("outlier_val", name, &rec.outlier_val)?;
        if idx.len() != val.len() {
            return Err(corrupt(
                "outlier_idx",
                "outlier index/value count mismatch".into(),
            ));
        }
        if idx.iter().any(|&i| (i as usize) >= rec.n) {
            return Err(corrupt(
                "outlier_idx",
                "outlier index out of range".into(),
            ));
        }

        if let Some(g) = &rec.grid {
            // grid form: the codebook section is the dense-slot codepoint
            // table and decode is a direct gather — NOT through
            // `Codebook`, which sorts its points (dense slots are in
            // first-occurrence order)
            if rec.transposed {
                return Err(invalid(format!(
                    "{name}: grid tensors are tensor-granularity \
                     (never transposed)"
                )));
            }
            if !scales.is_empty() || !idx.is_empty() || !val.is_empty() {
                return Err(invalid(format!(
                    "{name}: grid tensors carry no scales or outliers"
                )));
            }
            if g.buckets.len() != points.len() {
                return Err(invalid(format!(
                    "{name}: {} grid buckets for {} codepoints",
                    g.buckets.len(),
                    points.len()
                )));
            }
            // cross-check the persisted table against the hex-exact δ:
            // every codepoint must be exactly dequantise(bucket), the
            // invariant the gather's bit-identity rests on
            let grid = crate::compress::grid::UniformGrid::new(g.delta);
            for (slot, (&b, &p)) in
                g.buckets.iter().zip(points.iter()).enumerate()
            {
                if grid.dequantise(b).to_bits() != p.to_bits() {
                    return Err(invalid(format!(
                        "{name}: slot {slot} codepoint disagrees with \
                         the recorded δ"
                    )));
                }
            }
            for (o, &s) in out.iter_mut().zip(&indices) {
                *o = points[s as usize];
            }
        } else {
            let groups =
                scale_groups(rec.n, scheme.granularity, rec.channel_len);
            if scales.len() != groups.len() {
                return Err(corrupt(
                    "scales",
                    format!(
                        "{} scales for {} groups",
                        scales.len(),
                        groups.len()
                    ),
                ));
            }
            let codebook = crate::formats::Codebook::with_bits(
                points,
                rec.storage_bits,
            );
            let quantiser = Quantiser::new(
                scheme.granularity,
                scheme.statistic,
                scheme.scale_format,
                codebook,
            )
            .with_multiplier(rec.multiplier);
            let enc = Encoded {
                scales,
                indices,
                groups,
            };

            if rec.transposed {
                // layout space is the transpose; decode + scatter there,
                // then permute into the caller's row-major buffer (the
                // exact restore_layout permutation — values bit-identical)
                let mut buf = vec![0f32; rec.n];
                quantiser.decode_into(&enc, &mut buf);
                for (&i, &v) in idx.iter().zip(&val) {
                    buf[i as usize] = v;
                }
                let (rows, cols) = (rec.shape[0], rec.shape[1]);
                for c in 0..cols {
                    for r in 0..rows {
                        out[r * cols + c] = buf[c * rows + r];
                    }
                }
            } else {
                quantiser.decode_into(&enc, out);
                for (&i, &v) in idx.iter().zip(&val) {
                    out[i as usize] = v;
                }
            }
        }

        apply_inverse_rotation(rec, out);
        Ok(())
    }

    /// Decode a mixed (fractional-allocation) tensor into layout space:
    /// read and validate the per-block scheme-id stream, split the
    /// concatenated sections by the manifest's per-part lengths
    /// (cross-checked against the id stream), run each part through the
    /// same entropy decode + fused [`Quantiser::decode_into`] a pure
    /// tensor uses, and scatter the part reconstructions back onto their
    /// blocks.  Every length or id inconsistency surfaces as a typed
    /// error naming the narrowest responsible section.
    fn decode_mixed(
        &self,
        rec: &TensorRecord,
        scheme: &Scheme,
        out: &mut [f32],
    ) -> AResult<()> {
        let name = &rec.name;
        let corrupt = |section: &str, detail: String| {
            ArtifactError::corrupt(name, section, detail)
        };
        let mix = rec.mix.as_ref().expect("decode_mixed without mix");
        let bs = rec
            .block_schemes
            .as_ref()
            .expect("mix/block_schemes pairing validated at open");

        // the scheme-id table: every part must share the base layout
        let mut parts: Vec<Scheme> = Vec::with_capacity(mix.specs.len());
        for spec in &mix.specs {
            let s = Scheme::parse(spec).map_err(|e| {
                invalid(format!("{name}: mix spec {spec:?}: {e}"))
            })?;
            if s.granularity != scheme.granularity {
                return Err(invalid(format!(
                    "{name}: mix part {spec:?} granularity disagrees \
                     with the tensor spec"
                )));
            }
            if s.element == Element::Grid {
                return Err(invalid(format!(
                    "{name}: grid schemes cannot be mixed"
                )));
            }
            if s.sparse > 0.0 || s.rotate != scheme.rotate {
                return Err(invalid(format!(
                    "{name}: mix part {spec:?} flags disagree with the \
                     tensor spec"
                )));
            }
            parts.push(s);
        }
        if !matches!(
            scheme.granularity,
            crate::scaling::Granularity::Block(_)
        ) {
            return Err(invalid(format!(
                "{name}: mixed tensors require block granularity"
            )));
        }

        let blocks =
            scale_groups(rec.n, scheme.granularity, rec.channel_len);
        let assign = self.section("block_schemes", name, bs)?;
        if assign.len() != blocks.len() {
            return Err(corrupt(
                "block_schemes",
                format!(
                    "{} scheme ids for {} blocks",
                    assign.len(),
                    blocks.len()
                ),
            ));
        }
        if let Some(&id) =
            assign.iter().find(|&&id| (id as usize) >= parts.len())
        {
            return Err(corrupt(
                "block_schemes",
                format!(
                    "scheme id {id} out of range ({} parts)",
                    parts.len()
                ),
            ));
        }

        // per-part element counts and group structure from the id stream,
        // cross-checked against the manifest record
        let k = parts.len();
        let mut elems = vec![0usize; k];
        let mut group_lens: Vec<Vec<usize>> = vec![Vec::new(); k];
        for (&id, &(_, len)) in assign.iter().zip(&blocks) {
            elems[id as usize] += len;
            group_lens[id as usize].push(len);
        }
        for (p, &e) in elems.iter().enumerate() {
            if e != mix.part_elems[p] {
                return Err(corrupt(
                    "block_schemes",
                    format!(
                        "part {p} owns {e} elements, manifest says {}",
                        mix.part_elems[p]
                    ),
                ));
            }
            if e == 0 {
                return Err(invalid(format!(
                    "{name}: mix part {p} is assigned no blocks"
                )));
            }
        }

        // split the concatenated sections by the recorded part lengths
        let points_all =
            self.f32_section("codebook", name, &rec.codebook)?;
        if points_all.len() != mix.points_len.iter().sum::<usize>() {
            return Err(corrupt(
                "codebook",
                format!(
                    "{} codepoints for recorded part lengths {:?}",
                    points_all.len(),
                    mix.points_len
                ),
            ));
        }
        let counts_all = self.u64_section("counts", name, &rec.counts)?;
        if counts_all.len() != points_all.len() {
            return Err(corrupt(
                "counts",
                format!(
                    "histogram covers {} of {} codepoints",
                    counts_all.len(),
                    points_all.len()
                ),
            ));
        }
        let scales_all = self.f32_section("scales", name, &rec.scales)?;
        let n_groups_total: usize =
            group_lens.iter().map(Vec::len).sum();
        if scales_all.len() != n_groups_total {
            return Err(corrupt(
                "scales",
                format!(
                    "{} scales for {} groups",
                    scales_all.len(),
                    n_groups_total
                ),
            ));
        }
        let payload_all = self.section("payload", name, &rec.payload)?;
        if payload_all.len() != mix.payload_len.iter().sum::<usize>() {
            return Err(corrupt(
                "payload",
                format!(
                    "{} payload bytes for recorded part lengths {:?}",
                    payload_all.len(),
                    mix.payload_len
                ),
            ));
        }
        if rec.outlier_idx.len != 0 || rec.outlier_val.len != 0 {
            return Err(invalid(format!(
                "{name}: mixed tensors carry no outliers"
            )));
        }

        let (mut p_off, mut c_off, mut s_off, mut pay_off) = (0, 0, 0, 0);
        for p in 0..k {
            let np = mix.points_len[p];
            let points = points_all[p_off..p_off + np].to_vec();
            if points.is_empty() {
                return Err(corrupt(
                    "codebook",
                    format!("part {p}: empty codebook"),
                ));
            }
            let counts = &counts_all[c_off..c_off + np];
            if counts.iter().sum::<u64>() as usize != elems[p] {
                return Err(corrupt(
                    "counts",
                    format!(
                        "part {p}: histogram does not cover its elements"
                    ),
                ));
            }
            let n_groups = group_lens[p].len();
            let scales = scales_all[s_off..s_off + n_groups].to_vec();
            let payload =
                &payload_all[pay_off..pay_off + mix.payload_len[p]];
            let indices =
                self.decode_indices_bytes(name, counts, payload, elems[p])?;
            if indices.len() != elems[p] {
                return Err(corrupt(
                    "payload",
                    format!(
                        "part {p}: decoded {} of {} indices",
                        indices.len(),
                        elems[p]
                    ),
                ));
            }
            // groups over the gathered stream: block order is preserved,
            // so group g starts where group g−1 ended
            let mut groups = Vec::with_capacity(n_groups);
            let mut start = 0usize;
            for &l in &group_lens[p] {
                groups.push((start, l));
                start += l;
            }
            let codebook = crate::formats::Codebook::with_bits(
                points,
                mix.storage_bits[p],
            );
            let quantiser = Quantiser::new(
                parts[p].granularity,
                parts[p].statistic,
                parts[p].scale_format,
                codebook,
            )
            .with_multiplier(mix.multipliers[p]);
            let enc = Encoded {
                scales,
                indices,
                groups,
            };
            let mut buf = vec![0f32; elems[p]];
            quantiser.decode_into(&enc, &mut buf);
            let mut cursor = 0usize;
            for (&id, &(bstart, blen)) in assign.iter().zip(&blocks) {
                if id as usize == p {
                    out[bstart..bstart + blen]
                        .copy_from_slice(&buf[cursor..cursor + blen]);
                    cursor += blen;
                }
            }
            p_off += np;
            c_off += np;
            s_off += n_groups;
            pay_off += mix.payload_len[p];
        }
        Ok(())
    }

    /// The checksum-verified per-block scheme-id stream of a mixed tensor
    /// (`None` for plain tensors).  `owf inspect --verify` and the bench
    /// parity gates use this to rebuild the in-memory mixed pipeline for
    /// the bit-identity comparison.
    pub fn block_assignment(&self, i: usize) -> AResult<Option<Vec<u8>>> {
        let rec = &self.tensors[i];
        match &rec.block_schemes {
            Some(s) => Ok(Some(
                self.section("block_schemes", &rec.name, s)?.into_owned(),
            )),
            None => Ok(None),
        }
    }

    /// Entropy-decode the index payload under the stored histogram model.
    /// Runs under its own `catch_unwind` so a coder panic on
    /// checksum-evading damage names the `payload` section specifically.
    fn decode_indices(
        &self,
        rec: &TensorRecord,
        counts: &[u64],
    ) -> AResult<Vec<u16>> {
        let payload = self.section("payload", &rec.name, &rec.payload)?;
        self.decode_indices_bytes(&rec.name, counts, &payload, rec.n)
    }

    /// [`Self::decode_indices`] over an explicit byte slice and element
    /// count — the mixed decode path calls this once per part with its
    /// slice of the concatenated payload section.
    fn decode_indices_bytes(
        &self,
        name: &str,
        counts: &[u64],
        payload: &[u8],
        n: usize,
    ) -> AResult<Vec<u16>> {
        let decoded = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                self.decode_indices_inner(name, counts, payload, n)
            }),
        );
        match decoded {
            Ok(result) => result,
            Err(p) => Err(ArtifactError::corrupt(
                name,
                "payload",
                format!("entropy decoder panic: {}", panic_message(&*p)),
            )),
        }
    }

    fn decode_indices_inner(
        &self,
        name: &str,
        counts: &[u64],
        payload: &[u8],
        n: usize,
    ) -> AResult<Vec<u16>> {
        match self.codec {
            Codec::Raw => {
                if payload.len() != 2 * n {
                    return Err(ArtifactError::corrupt(
                        name,
                        "payload",
                        format!(
                            "raw payload holds {} of {} bytes",
                            payload.len(),
                            2 * n
                        ),
                    ));
                }
                // compare in usize: a full 2^16-symbol alphabet would wrap
                // a u16 bound to 0 and reject every valid index
                let k = counts.len();
                let indices: Vec<u16> = payload
                    .chunks_exact(2)
                    .map(|c| u16::from_le_bytes([c[0], c[1]]))
                    .collect();
                if indices.iter().any(|&i| (i as usize) >= k) {
                    return Err(ArtifactError::corrupt(
                        name,
                        "payload",
                        "raw index out of codebook range",
                    ));
                }
                Ok(indices)
            }
            Codec::Huffman => {
                if payload.is_empty() {
                    return Err(ArtifactError::corrupt(
                        name,
                        "payload",
                        "empty Huffman payload",
                    ));
                }
                let code = crate::compress::tables::huffman_for(counts);
                code.decoder()
                    .decode_interleaved_checked(payload, n)
                    .map_err(|e| {
                        ArtifactError::corrupt(name, "payload", e)
                    })
            }
            Codec::Rans => {
                if payload.is_empty() {
                    return Err(ArtifactError::corrupt(
                        name,
                        "payload",
                        "empty rANS payload",
                    ));
                }
                let model = crate::compress::tables::rans_for(counts);
                crate::compress::rans::rans_decode_interleaved_checked(
                    &model, payload, n,
                )
                .map_err(|e| ArtifactError::corrupt(name, "payload", e))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_known_vectors() {
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        // incremental property: differs on any single-byte flip
        let base = fnv1a64(b"owq-artifact");
        let mut flipped = b"owq-artifact".to_vec();
        flipped[3] ^= 1;
        assert_ne!(base, fnv1a64(&flipped));
    }

    #[test]
    fn fnv_single_byte_change_never_collides() {
        // The bijection argument behind the single-bit-flip guarantee:
        // exhaustively check a small input against every 1-byte variant.
        let base = b"owq";
        let h = fnv1a64(base);
        for pos in 0..base.len() {
            for delta in 1..=255u8 {
                let mut v = base.to_vec();
                v[pos] ^= delta;
                assert_ne!(fnv1a64(&v), h, "collision at {pos} ^ {delta}");
            }
        }
    }

    #[test]
    fn hex_f64_roundtrip_is_exact() {
        for x in [
            0.0f64,
            -0.0,
            1.0,
            4.25,
            std::f64::consts::PI,
            1e-308,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::MAX,
        ] {
            let h = f64_to_hex(x);
            assert_eq!(
                f64_from_hex(&h).unwrap().to_bits(),
                x.to_bits(),
                "{x}"
            );
        }
        // NaN preserves its exact payload
        let nan = f64::from_bits(0x7ff8dead_beef0001);
        assert_eq!(
            f64_from_hex(&f64_to_hex(nan)).unwrap().to_bits(),
            nan.to_bits()
        );
        assert!(f64_from_hex("xyz").is_err());
        assert!(f64_from_hex("0123").is_err());
    }

    #[test]
    fn codec_names_roundtrip() {
        for c in [Codec::Raw, Codec::Huffman, Codec::Rans] {
            assert_eq!(Codec::parse(c.name()).unwrap(), c);
        }
        assert!(Codec::parse("zstd").is_err());
    }

    #[test]
    fn garbage_bytes_rejected_with_typed_errors() {
        // wrong magic → torn (not our container)
        let err = Artifact::from_bytes(b"NOPE....".to_vec()).unwrap_err();
        assert!(matches!(err, ArtifactError::TornContainer { .. }), "{err}");
        // empty file → torn
        let err = Artifact::from_bytes(Vec::new()).unwrap_err();
        assert!(matches!(err, ArtifactError::TornContainer { .. }), "{err}");
        // magic ok but manifest length runs past the end → torn
        let mut torn = Vec::new();
        torn.extend_from_slice(MAGIC);
        torn.extend_from_slice(&1000u32.to_le_bytes());
        torn.extend_from_slice(b"{}");
        let err = Artifact::from_bytes(torn).unwrap_err();
        assert!(matches!(err, ArtifactError::TornContainer { .. }), "{err}");
        // intact framing, damaged manifest byte → corrupt on `manifest`
        let manifest = br#"{"version":1}"#;
        let mut raw = Vec::new();
        raw.extend_from_slice(MAGIC);
        raw.extend_from_slice(&(manifest.len() as u32).to_le_bytes());
        raw.extend_from_slice(manifest);
        raw.extend_from_slice(&fnv1a64(manifest).to_le_bytes());
        let mut bad = raw.clone();
        bad[10] ^= 0x40;
        let err = Artifact::from_bytes(bad).unwrap_err();
        match &err {
            ArtifactError::Corrupt { section, .. } => {
                assert_eq!(section, "manifest");
            }
            other => panic!("expected manifest corruption, got {other}"),
        }
    }
}
