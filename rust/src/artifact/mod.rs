//! The `OWQ1` quantised-artifact store: a durable, self-describing
//! container for entropy-coded quantised tensors, and the serving-side
//! reader that streams them back through the fused decode kernels.
//!
//! This is where the in-memory pipeline (`eval::pipeline`) meets disk:
//! [`writer::pack_store`] runs the *same* quantiser construction, outlier
//! selection and fused encode as the in-memory qdq path (via
//! [`crate::eval::pipeline::encode_tensor`]) and persists the result;
//! [`Artifact`] decodes any tensor lazily, bit-identical to what
//! `qdq_tensor` would have produced; [`server::ArtifactServer`] wraps the
//! reader for concurrent serving with an LRU decoded-tensor cache.
//!
//! # Byte layout (also documented in `EXPERIMENTS.md` §Artifact)
//!
//! ```text
//! [0..4)            magic  b"OWQ1"
//! [4..8)            manifest byte length M, u32 LE
//! [8..8+M)          manifest, UTF-8 JSON
//! [8+M..8+M+8)      FNV-1a 64 of the manifest bytes, u64 LE
//! [8+M+8..)         payload: per-tensor sections, each 64-byte aligned
//!                   relative to the payload base, offsets in the manifest
//! ```
//!
//! Per tensor the manifest records name/shape/channel-axis, the resolved
//! scheme spec, layout (`channel_len`, `transposed`), the resolved scale
//! multiplier, storage bits, honest bits accounting and the pipeline
//! sq-err (all four f64s as 16-hex-digit bit patterns — exact, no decimal
//! round-trip in the loop), plus six sections:
//!
//! | section       | contents                                  |
//! |---------------|-------------------------------------------|
//! | `codebook`    | sorted codepoints, f32 LE                 |
//! | `scales`      | per-group scales, f32 LE                  |
//! | `payload`     | indices: raw u16 LE, or a K-lane interleaved Huffman/rANS container |
//! | `counts`      | index histogram, u64 LE (the entropy model the payload was coded under) |
//! | `outlier_idx` | sorted outlier positions (layout space), u32 LE |
//! | `outlier_val` | exact outlier values, f32 LE              |
//!
//! Every section carries an FNV-1a 64 checksum in the manifest; the
//! manifest itself is checksummed in the header.  Truncated files fail at
//! [`Artifact::open`] (section bounds are validated eagerly); corrupted
//! bytes fail at first decode of the affected tensor (checksums are
//! verified lazily, per section read — [`Artifact::verify_all`] forces
//! them all).  Checksum verification runs *before* entropy decoding, so
//! the panicking coder paths only ever see writer-produced bytes.

pub mod server;
pub mod writer;

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::config::Scheme;
use crate::quant::{Encoded, Quantiser};
use crate::scaling::scale_groups;
use crate::util::json::Json;

pub const MAGIC: &[u8; 4] = b"OWQ1";
pub const VERSION: usize = 1;
/// Section alignment within the payload region (matches `.owt`).
pub const ALIGN: usize = 64;

/// FNV-1a 64-bit — the container checksum (from scratch; no external
/// crates offline).  Not cryptographic: it detects torn writes and bit
/// rot, which is the failure model for a local artifact store.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Exact f64 interchange: 16 hex digits of the IEEE bit pattern.  Used for
/// the multiplier / storage-bits / bits / sq-err manifest fields so
/// "bit-identical to the in-memory pipeline" survives serialisation
/// (and NaN/∞ never hit the JSON number grammar).
pub fn f64_to_hex(x: f64) -> String {
    format!("{:016x}", x.to_bits())
}

pub fn f64_from_hex(s: &str) -> Result<f64> {
    ensure!(s.len() == 16, "bad f64 hex field {s:?}");
    let bits = u64::from_str_radix(s, 16)
        .with_context(|| format!("bad f64 hex field {s:?}"))?;
    Ok(f64::from_bits(bits))
}

pub fn u64_to_hex(x: u64) -> String {
    format!("{x:016x}")
}

pub fn u64_from_hex(s: &str) -> Result<u64> {
    ensure!(s.len() == 16, "bad u64 hex field {s:?}");
    u64::from_str_radix(s, 16)
        .with_context(|| format!("bad u64 hex field {s:?}"))
}

/// Index-payload codec of a container (one per artifact).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Codec {
    /// Indices stored verbatim as u16 LE (no entropy coding).
    Raw,
    /// K-lane interleaved canonical Huffman
    /// ([`crate::compress::huffman::HuffmanCode::encode_interleaved`]).
    Huffman,
    /// K interleaved rANS states over one shared stream
    /// ([`crate::compress::rans::rans_encode_interleaved`]).
    Rans,
}

impl Codec {
    pub fn name(&self) -> &'static str {
        match self {
            Codec::Raw => "raw",
            Codec::Huffman => "huffman",
            Codec::Rans => "rans",
        }
    }

    pub fn parse(s: &str) -> Result<Codec> {
        match s {
            "raw" => Ok(Codec::Raw),
            "huffman" => Ok(Codec::Huffman),
            "rans" => Ok(Codec::Rans),
            other => bail!("unknown codec {other:?} (raw|huffman|rans)"),
        }
    }
}

/// One checksummed byte range in the payload region.
#[derive(Clone, Copy, Debug)]
pub struct Section {
    pub off: usize,
    pub len: usize,
    pub fnv: u64,
}

/// Manifest record of one packed tensor.
#[derive(Clone, Debug)]
pub struct TensorRecord {
    pub name: String,
    pub shape: Vec<usize>,
    pub channel_axis: Option<usize>,
    /// Resolved per-tensor scheme spec (bits may differ from the pack spec
    /// under variable allocation); parses back through [`Scheme::parse`].
    pub spec: String,
    pub n: usize,
    /// Resolved scale multiplier (search already performed at pack time).
    pub multiplier: f64,
    pub storage_bits: f64,
    /// Layout channel-group length (0 for non-channel granularities).
    pub channel_len: usize,
    pub transposed: bool,
    /// Honest bits/element, same accounting as the in-memory pipeline.
    pub bits: f64,
    /// Pipeline sq-err vs the source tensor, bit-exact.
    pub sq_err: f64,
    pub codebook: Section,
    pub scales: Section,
    pub payload: Section,
    pub counts: Section,
    pub outlier_idx: Section,
    pub outlier_val: Section,
}

impl TensorRecord {
    pub fn numel(&self) -> usize {
        self.n
    }

    fn sections(&self) -> [(&'static str, &Section); 6] {
        [
            ("codebook", &self.codebook),
            ("scales", &self.scales),
            ("payload", &self.payload),
            ("counts", &self.counts),
            ("outlier_idx", &self.outlier_idx),
            ("outlier_val", &self.outlier_val),
        ]
    }
}

/// The bit-allocation record carried in the manifest (eq. 5 / fig. 6).
#[derive(Clone, Debug)]
pub struct AllocRecord {
    /// "flat" or "variable".
    pub scheme: String,
    pub target: f64,
    pub average: f64,
    /// Per-tensor bit widths, same order as `tensors`.
    pub bits: Vec<f64>,
}

/// A parsed `OWQ1` container: manifest + in-memory payload, with lazy
/// per-tensor decoding.
pub struct Artifact {
    pub meta: Json,
    pub codec: Codec,
    pub lanes: usize,
    pub alloc: Option<AllocRecord>,
    pub tensors: Vec<TensorRecord>,
    index: HashMap<String, usize>,
    payload: Vec<u8>,
}

fn req(j: &Json, key: &str) -> Result<Json> {
    Ok(j.req(key).map_err(anyhow::Error::from)?.clone())
}

fn req_str(j: &Json, key: &str) -> Result<String> {
    Ok(j.req_str(key).map_err(anyhow::Error::from)?.to_string())
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.req_usize(key).map_err(anyhow::Error::from)
}

fn req_hex_f64(j: &Json, key: &str) -> Result<f64> {
    f64_from_hex(&req_str(j, key)?)
        .with_context(|| format!("field {key:?}"))
}

fn section_from(j: &Json, key: &str) -> Result<Section> {
    let s = j
        .get("sections")
        .and_then(|s| s.get(key))
        .with_context(|| format!("missing section {key:?}"))?;
    Ok(Section {
        off: req_usize(s, "off")?,
        len: req_usize(s, "len")?,
        fnv: u64_from_hex(&req_str(s, "fnv")?)
            .with_context(|| format!("section {key:?}"))?,
    })
}

impl Artifact {
    pub fn open(path: impl AsRef<Path>) -> Result<Artifact> {
        let path = path.as_ref();
        let raw = std::fs::read(path)
            .with_context(|| format!("open {path:?}"))?;
        Artifact::from_bytes(raw)
            .with_context(|| format!("parse {path:?}"))
    }

    /// Parse a container from raw bytes.  Structural problems — bad magic,
    /// torn manifest, manifest checksum mismatch, sections out of range —
    /// error here; payload *corruption* is caught at first decode of the
    /// affected tensor (per-section checksums).
    pub fn from_bytes(raw: Vec<u8>) -> Result<Artifact> {
        ensure!(
            raw.len() >= 8 && &raw[..4] == MAGIC,
            "not an OWQ1 container"
        );
        let mlen =
            u32::from_le_bytes([raw[4], raw[5], raw[6], raw[7]]) as usize;
        let base = 8 + mlen + 8;
        ensure!(
            raw.len() >= base,
            "torn container: {} of {base} header+manifest bytes",
            raw.len()
        );
        let manifest_bytes = &raw[8..8 + mlen];
        let want = u64::from_le_bytes(
            raw[8 + mlen..base].try_into().unwrap(),
        );
        ensure!(
            fnv1a64(manifest_bytes) == want,
            "manifest checksum mismatch (corrupt or torn container)"
        );
        let manifest = Json::parse(
            std::str::from_utf8(manifest_bytes)
                .context("manifest not utf-8")?,
        )
        .context("manifest parse")?;
        ensure!(
            req_usize(&manifest, "version")? == VERSION,
            "unsupported OWQ version"
        );
        let codec = Codec::parse(&req_str(&manifest, "codec")?)?;
        let lanes = req_usize(&manifest, "lanes")?;
        ensure!(
            (1..=crate::compress::MAX_LANES).contains(&lanes),
            "lane count {lanes} out of range"
        );
        let meta = manifest.get("meta").cloned().unwrap_or(Json::obj());
        let payload = raw[base..].to_vec();

        let mut tensors = Vec::new();
        let mut index = HashMap::new();
        for entry in req(&manifest, "tensors")?
            .as_arr()
            .context("tensors not an array")?
        {
            let name = req_str(entry, "name")?;
            let shape: Vec<usize> = req(entry, "shape")?
                .as_arr()
                .context("shape not an array")?
                .iter()
                .map(|j| j.as_usize().context("bad shape entry"))
                .collect::<Result<_>>()?;
            let channel_axis = entry
                .get("channel_axis")
                .filter(|j| !j.is_null())
                .and_then(|j| j.as_usize());
            let rec = TensorRecord {
                spec: req_str(entry, "spec")?,
                n: req_usize(entry, "n")?,
                multiplier: req_hex_f64(entry, "multiplier")?,
                storage_bits: req_hex_f64(entry, "storage_bits")?,
                channel_len: req_usize(entry, "channel_len")?,
                transposed: entry
                    .get("transposed")
                    .and_then(|j| j.as_bool())
                    .context("missing transposed flag")?,
                bits: req_hex_f64(entry, "bits")?,
                sq_err: req_hex_f64(entry, "sq_err")?,
                codebook: section_from(entry, "codebook")?,
                scales: section_from(entry, "scales")?,
                payload: section_from(entry, "payload")?,
                counts: section_from(entry, "counts")?,
                outlier_idx: section_from(entry, "outlier_idx")?,
                outlier_val: section_from(entry, "outlier_val")?,
                name: name.clone(),
                shape,
                channel_axis,
            };
            ensure!(
                rec.shape.iter().product::<usize>() == rec.n,
                "{name}: shape/numel mismatch"
            );
            ensure!(
                !rec.transposed || rec.shape.len() == 2,
                "{name}: transposed layout requires a 2-D shape"
            );
            for (sname, s) in rec.sections() {
                ensure!(
                    s.off.checked_add(s.len).is_some_and(|end| {
                        end <= payload.len()
                    }),
                    "{name}: section {sname} out of range (torn file?)"
                );
            }
            ensure!(
                index.insert(name, tensors.len()).is_none(),
                "duplicate tensor {:?}",
                rec.name
            );
            tensors.push(rec);
        }
        let alloc = match manifest.get("alloc") {
            None | Some(Json::Null) => None,
            Some(a) => Some(AllocRecord {
                scheme: req_str(a, "scheme")?,
                target: req_hex_f64(a, "target")?,
                average: req_hex_f64(a, "average")?,
                bits: req(a, "bits")?
                    .as_arr()
                    .context("alloc bits not an array")?
                    .iter()
                    .map(|j| {
                        j.as_str()
                            .context("alloc bit not hex")
                            .and_then(f64_from_hex)
                    })
                    .collect::<Result<_>>()?,
            }),
        };
        if let Some(a) = &alloc {
            ensure!(
                a.bits.len() == tensors.len(),
                "alloc record covers {} of {} tensors",
                a.bits.len(),
                tensors.len()
            );
        }
        Ok(Artifact {
            meta,
            codec,
            lanes,
            alloc,
            tensors,
            index,
            payload,
        })
    }

    pub fn position(&self, name: &str) -> Option<usize> {
        self.index.get(name).copied()
    }

    pub fn names(&self) -> Vec<&str> {
        self.tensors.iter().map(|t| t.name.as_str()).collect()
    }

    pub fn total_elements(&self) -> usize {
        self.tensors.iter().map(|t| t.n).sum()
    }

    pub fn payload_bytes(&self) -> usize {
        self.payload.len()
    }

    /// Fetch one section with its checksum verified.
    fn section(&self, name: &str, owner: &str, s: &Section) -> Result<&[u8]> {
        let bytes = &self.payload[s.off..s.off + s.len];
        ensure!(
            fnv1a64(bytes) == s.fnv,
            "{owner}: section {name} checksum mismatch (corrupt container)"
        );
        Ok(bytes)
    }

    fn f32_section(&self, name: &str, owner: &str, s: &Section) -> Result<Vec<f32>> {
        let bytes = self.section(name, owner, s)?;
        ensure!(bytes.len() % 4 == 0, "{owner}: ragged {name} section");
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn u64_section(&self, name: &str, owner: &str, s: &Section) -> Result<Vec<u64>> {
        let bytes = self.section(name, owner, s)?;
        ensure!(bytes.len() % 8 == 0, "{owner}: ragged {name} section");
        Ok(bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn u32_section(&self, name: &str, owner: &str, s: &Section) -> Result<Vec<u32>> {
        let bytes = self.section(name, owner, s)?;
        ensure!(bytes.len() % 4 == 0, "{owner}: ragged {name} section");
        Ok(bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Force every section checksum (the eager complement of the lazy
    /// per-decode verification).
    pub fn verify_all(&self) -> Result<()> {
        for rec in &self.tensors {
            for (sname, s) in rec.sections() {
                self.section(sname, &rec.name, s)?;
            }
        }
        Ok(())
    }

    /// Decode tensor `i` into a fresh buffer (original row-major layout).
    pub fn decode_tensor(&self, i: usize) -> Result<Vec<f32>> {
        let mut out = vec![0f32; self.tensors[i].n];
        self.decode_tensor_into(i, &mut out)?;
        Ok(out)
    }

    /// Decode tensor `i` into a caller-owned buffer: checksum-verified
    /// section reads → entropy decode (table-driven interleaved Huffman /
    /// K-state rANS / raw) → fused [`Quantiser::decode_into`] → outlier
    /// scatter-back → layout restore.  Bit-identical to the in-memory
    /// pipeline's reconstruction for the recorded spec (enforced by
    /// `rust/tests/artifact_props.rs` and the `scripts/check.sh` gate).
    pub fn decode_tensor_into(&self, i: usize, out: &mut [f32]) -> Result<()> {
        let rec = &self.tensors[i];
        let name = &rec.name;
        ensure!(
            out.len() == rec.n,
            "{name}: output buffer holds {} of {} elements",
            out.len(),
            rec.n
        );
        if rec.n == 0 {
            return Ok(());
        }
        let scheme = Scheme::parse(&rec.spec)
            .with_context(|| format!("{name}: stored spec"))?;
        let points = self.f32_section("codebook", name, &rec.codebook)?;
        ensure!(!points.is_empty(), "{name}: empty codebook");
        let counts = self.u64_section("counts", name, &rec.counts)?;
        ensure!(
            counts.len() == points.len(),
            "{name}: histogram/codebook length mismatch"
        );
        ensure!(
            counts.iter().sum::<u64>() as usize == rec.n,
            "{name}: index histogram does not cover the tensor"
        );
        let scales = self.f32_section("scales", name, &rec.scales)?;
        let indices = self.decode_indices(rec, &counts)?;
        ensure!(
            indices.len() == rec.n,
            "{name}: decoded {} of {} indices",
            indices.len(),
            rec.n
        );

        let groups =
            scale_groups(rec.n, scheme.granularity, rec.channel_len);
        ensure!(
            scales.len() == groups.len(),
            "{name}: {} scales for {} groups",
            scales.len(),
            groups.len()
        );
        let codebook = crate::formats::Codebook::with_bits(
            points,
            rec.storage_bits,
        );
        let quantiser = Quantiser::new(
            scheme.granularity,
            scheme.statistic,
            scheme.scale_format,
            codebook,
        )
        .with_multiplier(rec.multiplier);
        let enc = Encoded {
            scales,
            indices,
            groups,
        };

        let idx = self.u32_section("outlier_idx", name, &rec.outlier_idx)?;
        let val = self.f32_section("outlier_val", name, &rec.outlier_val)?;
        ensure!(
            idx.len() == val.len(),
            "{name}: outlier index/value count mismatch"
        );
        ensure!(
            idx.iter().all(|&i| (i as usize) < rec.n),
            "{name}: outlier index out of range"
        );

        if rec.transposed {
            // layout space is the transpose; decode + scatter there, then
            // permute into the caller's row-major buffer (the exact
            // restore_layout permutation — values bit-identical)
            let mut buf = vec![0f32; rec.n];
            quantiser.decode_into(&enc, &mut buf);
            for (&i, &v) in idx.iter().zip(&val) {
                buf[i as usize] = v;
            }
            let (rows, cols) = (rec.shape[0], rec.shape[1]);
            for c in 0..cols {
                for r in 0..rows {
                    out[r * cols + c] = buf[c * rows + r];
                }
            }
        } else {
            quantiser.decode_into(&enc, out);
            for (&i, &v) in idx.iter().zip(&val) {
                out[i as usize] = v;
            }
        }
        Ok(())
    }

    /// Entropy-decode the index payload under the stored histogram model.
    fn decode_indices(
        &self,
        rec: &TensorRecord,
        counts: &[u64],
    ) -> Result<Vec<u16>> {
        let name = &rec.name;
        let payload = self.section("payload", name, &rec.payload)?;
        match self.codec {
            Codec::Raw => {
                ensure!(
                    payload.len() == 2 * rec.n,
                    "{name}: raw payload holds {} of {} bytes",
                    payload.len(),
                    2 * rec.n
                );
                let k = counts.len() as u16;
                let indices: Vec<u16> = payload
                    .chunks_exact(2)
                    .map(|c| u16::from_le_bytes([c[0], c[1]]))
                    .collect();
                ensure!(
                    indices.iter().all(|&i| i < k),
                    "{name}: raw index out of codebook range"
                );
                Ok(indices)
            }
            Codec::Huffman => {
                ensure!(!payload.is_empty(), "{name}: empty Huffman payload");
                let code = crate::compress::tables::huffman_for(counts);
                Ok(code.decoder().decode_interleaved(payload, rec.n))
            }
            Codec::Rans => {
                ensure!(!payload.is_empty(), "{name}: empty rANS payload");
                let model = crate::compress::tables::rans_for(counts);
                Ok(crate::compress::rans::rans_decode_interleaved(
                    &model, payload, rec.n,
                ))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_known_vectors() {
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        // incremental property: differs on any single-byte flip
        let base = fnv1a64(b"owq-artifact");
        let mut flipped = b"owq-artifact".to_vec();
        flipped[3] ^= 1;
        assert_ne!(base, fnv1a64(&flipped));
    }

    #[test]
    fn hex_f64_roundtrip_is_exact() {
        for x in [
            0.0f64,
            -0.0,
            1.0,
            4.25,
            std::f64::consts::PI,
            1e-308,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::MAX,
        ] {
            let h = f64_to_hex(x);
            assert_eq!(
                f64_from_hex(&h).unwrap().to_bits(),
                x.to_bits(),
                "{x}"
            );
        }
        // NaN preserves its exact payload
        let nan = f64::from_bits(0x7ff8dead_beef0001);
        assert_eq!(
            f64_from_hex(&f64_to_hex(nan)).unwrap().to_bits(),
            nan.to_bits()
        );
        assert!(f64_from_hex("xyz").is_err());
        assert!(f64_from_hex("0123").is_err());
    }

    #[test]
    fn codec_names_roundtrip() {
        for c in [Codec::Raw, Codec::Huffman, Codec::Rans] {
            assert_eq!(Codec::parse(c.name()).unwrap(), c);
        }
        assert!(Codec::parse("zstd").is_err());
    }

    #[test]
    fn garbage_bytes_rejected() {
        assert!(Artifact::from_bytes(b"NOPE....".to_vec()).is_err());
        assert!(Artifact::from_bytes(Vec::new()).is_err());
        // magic ok but manifest length runs past the end
        let mut torn = Vec::new();
        torn.extend_from_slice(MAGIC);
        torn.extend_from_slice(&1000u32.to_le_bytes());
        torn.extend_from_slice(b"{}");
        assert!(Artifact::from_bytes(torn).is_err());
    }
}
