//! Bounded retry with exponential backoff for transient I/O.
//!
//! Only `ArtifactError::Io { transient: true }` is ever retried; corruption
//! and torn containers fail immediately (re-reading flipped bits does not
//! unflip them — they go to the quarantine map instead).  Sleeping goes
//! through an injectable [`Clock`] so tests assert the exact backoff
//! schedule without wall-clock time: [`RecordingClock`] captures the
//! requested durations, and [`GateClock`] turns a backoff sleep into a
//! rendezvous point for deterministic concurrency tests (a blocked retry
//! holds its decode permit, which lets tests pin `Overloaded` and
//! coalesced-waiter interleavings exactly).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use super::error::ArtifactError;

/// Injectable time source. Production uses [`SystemClock`]; tests inject
/// deterministic clocks so no test depends on real sleeps.
pub trait Clock: Send + Sync {
    fn sleep(&self, d: Duration);
}

/// Real wall-clock sleeps for production use.
pub struct SystemClock;

impl Clock for SystemClock {
    fn sleep(&self, d: Duration) {
        std::thread::sleep(d);
    }
}

/// Test clock: records every requested sleep, never actually sleeps.
#[derive(Default)]
pub struct RecordingClock {
    slept: Mutex<Vec<Duration>>,
}

impl RecordingClock {
    pub fn new() -> RecordingClock {
        RecordingClock::default()
    }

    pub fn slept(&self) -> Vec<Duration> {
        self.slept.lock().unwrap().clone()
    }
}

impl Clock for RecordingClock {
    fn sleep(&self, d: Duration) {
        self.slept.lock().unwrap().push(d);
    }
}

/// Test clock whose `sleep` blocks until the test opens the gate. The
/// sleeper count is observable, so a test can wait until a thread is
/// provably parked inside a retry backoff before acting.
pub struct GateClock {
    state: Mutex<GateState>,
    cv: Condvar,
}

struct GateState {
    open: bool,
    entered: u64,
    waiting: usize,
}

impl Default for GateClock {
    fn default() -> Self {
        GateClock {
            state: Mutex::new(GateState {
                open: false,
                entered: 0,
                waiting: 0,
            }),
            cv: Condvar::new(),
        }
    }
}

impl GateClock {
    pub fn new() -> GateClock {
        GateClock::default()
    }

    /// Total number of sleep calls observed so far.
    pub fn entered(&self) -> u64 {
        self.state.lock().unwrap().entered
    }

    /// Number of threads currently parked inside `sleep`.
    pub fn waiting(&self) -> usize {
        self.state.lock().unwrap().waiting
    }

    /// Release every current and future sleeper.
    pub fn open(&self) {
        self.state.lock().unwrap().open = true;
        self.cv.notify_all();
    }
}

impl Clock for GateClock {
    fn sleep(&self, _d: Duration) {
        let mut st = self.state.lock().unwrap();
        st.entered += 1;
        st.waiting += 1;
        while !st.open {
            st = self.cv.wait(st).unwrap();
        }
        st.waiting -= 1;
    }
}

/// Bounded exponential backoff: attempt `k` sleeps `base * 2^k`, capped at
/// `cap`. Deliberately jitter-free — retries must be exactly reproducible
/// in tests, and the fan-in here is per-process, not thundering-herd.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Total attempts including the first (>= 1).
    pub attempts: u32,
    /// Backoff before the first retry.
    pub base: Duration,
    /// Upper bound on any single backoff.
    pub cap: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            attempts: 3,
            base: Duration::from_millis(5),
            cap: Duration::from_millis(500),
        }
    }
}

impl RetryPolicy {
    /// No retries: fail on the first error of any class.
    pub fn none() -> RetryPolicy {
        RetryPolicy {
            attempts: 1,
            base: Duration::ZERO,
            cap: Duration::ZERO,
        }
    }

    /// Backoff to sleep after failed attempt index `attempt` (0-based).
    pub fn backoff(&self, attempt: u32) -> Duration {
        let factor = 1u32.checked_shl(attempt).unwrap_or(u32::MAX);
        self.base.saturating_mul(factor).min(self.cap)
    }
}

/// Run `op` under `policy`: transient I/O errors back off and retry (each
/// retry bumps `retries`); every other error — and transient errors once
/// attempts are exhausted — returns immediately.
pub fn with_retry<T>(
    policy: &RetryPolicy,
    clock: &dyn Clock,
    retries: &AtomicU64,
    mut op: impl FnMut() -> Result<T, ArtifactError>,
) -> Result<T, ArtifactError> {
    let attempts = policy.attempts.max(1);
    let mut attempt = 0u32;
    loop {
        match op() {
            Ok(v) => return Ok(v),
            Err(e) if e.is_transient_io() && attempt + 1 < attempts => {
                clock.sleep(policy.backoff(attempt));
                retries.fetch_add(1, Ordering::Relaxed);
                attempt += 1;
            }
            Err(e) => return Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn transient() -> ArtifactError {
        ArtifactError::Io {
            transient: true,
            detail: "injected".into(),
        }
    }

    #[test]
    fn backoff_is_exponential_and_capped() {
        let p = RetryPolicy {
            attempts: 8,
            base: Duration::from_millis(10),
            cap: Duration::from_millis(70),
        };
        assert_eq!(p.backoff(0), Duration::from_millis(10));
        assert_eq!(p.backoff(1), Duration::from_millis(20));
        assert_eq!(p.backoff(2), Duration::from_millis(40));
        assert_eq!(p.backoff(3), Duration::from_millis(70)); // capped
        assert_eq!(p.backoff(31), Duration::from_millis(70));
        assert_eq!(p.backoff(40), Duration::from_millis(70)); // shl overflow
    }

    #[test]
    fn retries_transient_then_succeeds() {
        let clock = RecordingClock::new();
        let retries = AtomicU64::new(0);
        let p = RetryPolicy {
            attempts: 4,
            base: Duration::from_millis(10),
            cap: Duration::from_secs(1),
        };
        let mut left = 2;
        let out = with_retry(&p, &clock, &retries, || {
            if left > 0 {
                left -= 1;
                Err(transient())
            } else {
                Ok(7)
            }
        });
        assert_eq!(out.unwrap(), 7);
        assert_eq!(retries.load(Ordering::Relaxed), 2);
        assert_eq!(
            clock.slept(),
            vec![Duration::from_millis(10), Duration::from_millis(20)]
        );
    }

    #[test]
    fn exhausts_attempts_on_persistent_transient() {
        let clock = RecordingClock::new();
        let retries = AtomicU64::new(0);
        let p = RetryPolicy {
            attempts: 3,
            base: Duration::from_millis(1),
            cap: Duration::from_secs(1),
        };
        let out: Result<(), _> =
            with_retry(&p, &clock, &retries, || Err(transient()));
        assert!(out.unwrap_err().is_transient_io());
        assert_eq!(retries.load(Ordering::Relaxed), 2);
        assert_eq!(clock.slept().len(), 2);
    }

    #[test]
    fn corruption_never_retries() {
        let clock = RecordingClock::new();
        let retries = AtomicU64::new(0);
        let p = RetryPolicy::default();
        let mut calls = 0;
        let out: Result<(), _> = with_retry(&p, &clock, &retries, || {
            calls += 1;
            Err(ArtifactError::corrupt("t", "payload", "flip"))
        });
        assert!(out.unwrap_err().is_corrupt());
        assert_eq!(calls, 1, "corruption must fail on the first attempt");
        assert_eq!(retries.load(Ordering::Relaxed), 0);
        assert!(clock.slept().is_empty(), "corruption must never sleep");
    }

    #[test]
    fn permanent_io_never_retries() {
        let clock = RecordingClock::new();
        let retries = AtomicU64::new(0);
        let mut calls = 0;
        let out: Result<(), _> =
            with_retry(&RetryPolicy::default(), &clock, &retries, || {
                calls += 1;
                Err(ArtifactError::Io {
                    transient: false,
                    detail: "enospc".into(),
                })
            });
        assert!(!out.unwrap_err().is_transient_io());
        assert_eq!(calls, 1);
        assert!(clock.slept().is_empty());
    }
}
