//! Bounded retry with exponential backoff for transient I/O, plus the
//! deadline arithmetic the serving layer's queue and coalescing waits are
//! bounded by.
//!
//! Only `ArtifactError::Io { transient: true }` is ever retried; corruption
//! and torn containers fail immediately (re-reading flipped bits does not
//! unflip them — they go to the quarantine map instead).  Sleeping goes
//! through an injectable [`Clock`] so tests assert the exact backoff
//! schedule without wall-clock time: [`RecordingClock`] captures the
//! requested durations, and [`GateClock`] turns a backoff sleep into a
//! rendezvous point for deterministic concurrency tests (a blocked retry
//! holds its decode permit, which lets tests pin `Overloaded` and
//! coalesced-waiter interleavings exactly).
//!
//! The [`Clock`] also carries a monotonic [`Clock::now`] so deadlines are
//! absolute instants on the *injected* timeline: production reads the
//! process-monotonic clock, [`RecordingClock`] advances virtual time by
//! every sleep it records (so a retry backoff deterministically "takes"
//! its backoff duration — how `tests/queue_props.rs` manufactures slow
//! decodes without wall-clock time), and [`GateClock`] advances only when
//! the test calls [`GateClock::advance`] (so a test can expire a deadline
//! while a decode owner is provably parked).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::error::ArtifactError;

/// Injectable time source. Production uses [`SystemClock`]; tests inject
/// deterministic clocks so no test depends on real sleeps.
pub trait Clock: Send + Sync {
    fn sleep(&self, d: Duration);

    /// Monotonic elapsed time on this clock's timeline.  Deadlines are
    /// absolute `now()` values, so virtual clocks make deadline expiry
    /// exactly reproducible.
    fn now(&self) -> Duration;
}

/// One process-wide monotonic epoch so every [`SystemClock`] shares a
/// timeline (a `Deadline` minted by one instance is meaningful to any
/// other).
fn process_epoch() -> Instant {
    static EPOCH: Mutex<Option<Instant>> = Mutex::new(None);
    let mut e = EPOCH.lock().unwrap();
    *e.get_or_insert_with(Instant::now)
}

/// Real wall-clock sleeps for production use.
pub struct SystemClock;

impl Clock for SystemClock {
    fn sleep(&self, d: Duration) {
        std::thread::sleep(d);
    }

    fn now(&self) -> Duration {
        process_epoch().elapsed()
    }
}

/// Test clock: records every requested sleep, never actually sleeps —
/// but each sleep advances virtual time by the requested duration, so a
/// code path that backs off `5ms` observably "took" 5ms of virtual time.
#[derive(Default)]
pub struct RecordingClock {
    state: Mutex<RecordingState>,
}

#[derive(Default)]
struct RecordingState {
    slept: Vec<Duration>,
    now: Duration,
}

impl RecordingClock {
    pub fn new() -> RecordingClock {
        RecordingClock::default()
    }

    pub fn slept(&self) -> Vec<Duration> {
        self.state.lock().unwrap().slept.clone()
    }

    /// Advance virtual time without recording a sleep (test control for
    /// breaker cooldowns and deadline expiry).
    pub fn advance(&self, d: Duration) {
        self.state.lock().unwrap().now += d;
    }
}

impl Clock for RecordingClock {
    fn sleep(&self, d: Duration) {
        let mut st = self.state.lock().unwrap();
        st.slept.push(d);
        st.now += d;
    }

    fn now(&self) -> Duration {
        self.state.lock().unwrap().now
    }
}

/// Test clock whose `sleep` blocks until the test opens the gate. The
/// sleeper count is observable, so a test can wait until a thread is
/// provably parked inside a retry backoff before acting.
pub struct GateClock {
    state: Mutex<GateState>,
    cv: Condvar,
}

struct GateState {
    open: bool,
    entered: u64,
    waiting: usize,
    now: Duration,
}

impl Default for GateClock {
    fn default() -> Self {
        GateClock {
            state: Mutex::new(GateState {
                open: false,
                entered: 0,
                waiting: 0,
                now: Duration::ZERO,
            }),
            cv: Condvar::new(),
        }
    }
}

impl GateClock {
    pub fn new() -> GateClock {
        GateClock::default()
    }

    /// Total number of sleep calls observed so far.
    pub fn entered(&self) -> u64 {
        self.state.lock().unwrap().entered
    }

    /// Number of threads currently parked inside `sleep`.
    pub fn waiting(&self) -> usize {
        self.state.lock().unwrap().waiting
    }

    /// Release every current and future sleeper.
    pub fn open(&self) {
        self.state.lock().unwrap().open = true;
        self.cv.notify_all();
    }

    /// Advance virtual time.  Sleepers stay parked (the gate, not time,
    /// releases them); deadline-bounded waits observe the new `now` on
    /// their next poll tick.
    pub fn advance(&self, d: Duration) {
        self.state.lock().unwrap().now += d;
    }
}

impl Clock for GateClock {
    fn sleep(&self, _d: Duration) {
        let mut st = self.state.lock().unwrap();
        st.entered += 1;
        st.waiting += 1;
        while !st.open {
            st = self.cv.wait(st).unwrap();
        }
        st.waiting -= 1;
    }

    fn now(&self) -> Duration {
        self.state.lock().unwrap().now
    }
}

/// An absolute instant on a [`Clock`]'s timeline, after which a queued or
/// coalescing request must resolve typed instead of waiting on.  A
/// deadline bounds *waiting* — work already admitted runs to completion
/// (a decode cannot be cancelled halfway through a tensor).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Deadline(Duration);

impl Deadline {
    /// Deadline at an absolute instant of the clock's timeline.
    pub fn at(instant: Duration) -> Deadline {
        Deadline(instant)
    }

    /// Deadline `timeout` from the clock's current `now`.
    pub fn after(clock: &dyn Clock, timeout: Duration) -> Deadline {
        Deadline(clock.now().saturating_add(timeout))
    }

    pub fn instant(&self) -> Duration {
        self.0
    }

    /// True the moment `now` reaches the deadline (inclusive, so a test
    /// advancing a virtual clock by exactly the timeout observes expiry).
    pub fn expired(&self, clock: &dyn Clock) -> bool {
        clock.now() >= self.0
    }

    pub fn remaining(&self, clock: &dyn Clock) -> Duration {
        self.0.saturating_sub(clock.now())
    }
}

/// Bounded exponential backoff: attempt `k` sleeps `base * 2^k`, capped at
/// `cap`. Deliberately jitter-free — retries must be exactly reproducible
/// in tests, and the fan-in here is per-process, not thundering-herd.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Total attempts including the first (>= 1).
    pub attempts: u32,
    /// Backoff before the first retry.
    pub base: Duration,
    /// Upper bound on any single backoff.
    pub cap: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            attempts: 3,
            base: Duration::from_millis(5),
            cap: Duration::from_millis(500),
        }
    }
}

impl RetryPolicy {
    /// No retries: fail on the first error of any class.
    pub fn none() -> RetryPolicy {
        RetryPolicy {
            attempts: 1,
            base: Duration::ZERO,
            cap: Duration::ZERO,
        }
    }

    /// Backoff to sleep after failed attempt index `attempt` (0-based).
    pub fn backoff(&self, attempt: u32) -> Duration {
        let factor = 1u32.checked_shl(attempt).unwrap_or(u32::MAX);
        self.base.saturating_mul(factor).min(self.cap)
    }
}

/// Run `op` under `policy`: transient I/O errors back off and retry (each
/// retry bumps `retries`); every other error — and transient errors once
/// attempts are exhausted — returns immediately.
pub fn with_retry<T>(
    policy: &RetryPolicy,
    clock: &dyn Clock,
    retries: &AtomicU64,
    mut op: impl FnMut() -> Result<T, ArtifactError>,
) -> Result<T, ArtifactError> {
    let attempts = policy.attempts.max(1);
    let mut attempt = 0u32;
    loop {
        match op() {
            Ok(v) => return Ok(v),
            Err(e) if e.is_transient_io() && attempt + 1 < attempts => {
                clock.sleep(policy.backoff(attempt));
                retries.fetch_add(1, Ordering::Relaxed);
                attempt += 1;
            }
            Err(e) => return Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn transient() -> ArtifactError {
        ArtifactError::Io {
            transient: true,
            detail: "injected".into(),
        }
    }

    #[test]
    fn backoff_is_exponential_and_capped() {
        let p = RetryPolicy {
            attempts: 8,
            base: Duration::from_millis(10),
            cap: Duration::from_millis(70),
        };
        assert_eq!(p.backoff(0), Duration::from_millis(10));
        assert_eq!(p.backoff(1), Duration::from_millis(20));
        assert_eq!(p.backoff(2), Duration::from_millis(40));
        assert_eq!(p.backoff(3), Duration::from_millis(70)); // capped
        assert_eq!(p.backoff(31), Duration::from_millis(70));
        assert_eq!(p.backoff(40), Duration::from_millis(70)); // shl overflow
    }

    #[test]
    fn retries_transient_then_succeeds() {
        let clock = RecordingClock::new();
        let retries = AtomicU64::new(0);
        let p = RetryPolicy {
            attempts: 4,
            base: Duration::from_millis(10),
            cap: Duration::from_secs(1),
        };
        let mut left = 2;
        let out = with_retry(&p, &clock, &retries, || {
            if left > 0 {
                left -= 1;
                Err(transient())
            } else {
                Ok(7)
            }
        });
        assert_eq!(out.unwrap(), 7);
        assert_eq!(retries.load(Ordering::Relaxed), 2);
        assert_eq!(
            clock.slept(),
            vec![Duration::from_millis(10), Duration::from_millis(20)]
        );
    }

    #[test]
    fn exhausts_attempts_on_persistent_transient() {
        let clock = RecordingClock::new();
        let retries = AtomicU64::new(0);
        let p = RetryPolicy {
            attempts: 3,
            base: Duration::from_millis(1),
            cap: Duration::from_secs(1),
        };
        let out: Result<(), _> =
            with_retry(&p, &clock, &retries, || Err(transient()));
        assert!(out.unwrap_err().is_transient_io());
        assert_eq!(retries.load(Ordering::Relaxed), 2);
        assert_eq!(clock.slept().len(), 2);
    }

    #[test]
    fn corruption_never_retries() {
        let clock = RecordingClock::new();
        let retries = AtomicU64::new(0);
        let p = RetryPolicy::default();
        let mut calls = 0;
        let out: Result<(), _> = with_retry(&p, &clock, &retries, || {
            calls += 1;
            Err(ArtifactError::corrupt("t", "payload", "flip"))
        });
        assert!(out.unwrap_err().is_corrupt());
        assert_eq!(calls, 1, "corruption must fail on the first attempt");
        assert_eq!(retries.load(Ordering::Relaxed), 0);
        assert!(clock.slept().is_empty(), "corruption must never sleep");
    }

    #[test]
    fn recording_clock_advances_virtual_time_by_sleeps() {
        let clock = RecordingClock::new();
        assert_eq!(clock.now(), Duration::ZERO);
        clock.sleep(Duration::from_millis(5));
        clock.sleep(Duration::from_millis(10));
        assert_eq!(clock.now(), Duration::from_millis(15));
        clock.advance(Duration::from_millis(100));
        assert_eq!(clock.now(), Duration::from_millis(115));
        assert_eq!(clock.slept().len(), 2, "advance records no sleep");
    }

    #[test]
    fn gate_clock_time_is_test_controlled() {
        let clock = GateClock::new();
        assert_eq!(clock.now(), Duration::ZERO);
        clock.advance(Duration::from_millis(30));
        assert_eq!(clock.now(), Duration::from_millis(30));
    }

    #[test]
    fn deadline_expiry_is_inclusive_and_exact() {
        let clock = RecordingClock::new();
        let d = Deadline::after(&clock, Duration::from_millis(50));
        assert!(!d.expired(&clock));
        assert_eq!(d.remaining(&clock), Duration::from_millis(50));
        clock.advance(Duration::from_millis(49));
        assert!(!d.expired(&clock));
        clock.advance(Duration::from_millis(1));
        assert!(d.expired(&clock), "expiry at exactly the instant");
        assert_eq!(d.remaining(&clock), Duration::ZERO);
        clock.advance(Duration::from_millis(1));
        assert!(d.expired(&clock));
    }

    #[test]
    fn system_clock_is_monotonic() {
        let clock = SystemClock;
        let a = clock.now();
        let b = clock.now();
        assert!(b >= a);
        // a second instance shares the process epoch
        let c = SystemClock.now();
        assert!(c >= b);
    }

    #[test]
    fn permanent_io_never_retries() {
        let clock = RecordingClock::new();
        let retries = AtomicU64::new(0);
        let mut calls = 0;
        let out: Result<(), _> =
            with_retry(&RetryPolicy::default(), &clock, &retries, || {
                calls += 1;
                Err(ArtifactError::Io {
                    transient: false,
                    detail: "enospc".into(),
                })
            });
        assert!(!out.unwrap_err().is_transient_io());
        assert_eq!(calls, 1);
        assert!(clock.slept().is_empty());
    }
}
