//! The OWQ pack path (container version 2, magic `OWQ1`): Fisher/RMS bit
//! allocation → the pipeline's fused encode
//! ([`crate::eval::pipeline::encode_tensor`], bit-identical to the
//! in-memory qdq) → K-lane interleaved entropy coding → checksummed
//! sections → crash-safe atomic write (temp file + rename, like
//! [`crate::tensorstore::Store::save`]).
//!
//! Every scheme the sweep grammar can produce is packable: codebook
//! families persist (codebook, scales, coded indices, histogram, outlier
//! overlay); `grid` schemes persist the dense-slot codepoint table in the
//! codebook section, the entropy-coded dense stream in the payload
//! section and the hex-exact δ + slot→bucket map in the manifest; `:rot`
//! schemes record the per-tensor rotation seed so the reader can re-derive
//! V/W and invert after decode.
//!
//! Failures are typed [`ArtifactError`]s: configuration problems (bad
//! spec, over-capacity rANS alphabet) are `Invalid`, write failures are
//! `Io` with transiency classified from the underlying `ErrorKind` (via
//! [`crate::util::fsx::atomic_write_io`], which preserves it).

use std::collections::HashMap;
use std::path::Path;

use super::{
    f64_to_hex, fnv1a64, u64_to_hex, AResult, ArtifactError, Codec, ALIGN,
    MAGIC, VERSION,
};
use crate::alloc::{
    round_allocation, variable_allocation, TensorInfo,
};
use crate::compress::rans::{rans_encode_interleaved, RANS_MAX_SYMBOLS};
use crate::compress::{tables, MAX_LANES};
use crate::coordinator::config::Scheme;
use crate::eval::pipeline::{encode_tensor, EncodedForm};
use crate::tensorstore::{Dtype, Store};
use crate::util::json::Json;

/// How per-tensor bit widths are chosen.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllocMode {
    /// Every tensor at the spec's bit width.
    Flat,
    /// Eq.-(5) Fisher/RMS variable allocation targeting the spec's bit
    /// width as the model-level average, rounded to integers by the
    /// largest-remainder rule ([`crate::alloc::round_allocation`]).
    Variable,
}

impl AllocMode {
    pub fn name(&self) -> &'static str {
        match self {
            AllocMode::Flat => "flat",
            AllocMode::Variable => "variable",
        }
    }

    pub fn parse(s: &str) -> AResult<AllocMode> {
        match s {
            "flat" => Ok(AllocMode::Flat),
            "variable" => Ok(AllocMode::Variable),
            other => Err(ArtifactError::invalid(format!(
                "unknown alloc mode {other:?} (flat|variable)"
            ))),
        }
    }
}

/// Pack configuration.
pub struct PackOptions {
    /// Base scheme spec — any spec the sweep grammar accepts, including
    /// `:rot` and `grid`.
    pub spec: String,
    pub alloc: AllocMode,
    pub codec: Codec,
    /// Interleaved lanes for the entropy-coded payload (ignored by
    /// [`Codec::Raw`]).
    pub lanes: usize,
    /// Free-form source description stored in the manifest (`owf pack`
    /// records enough here — sim seed/shapes/dist or checkpoint size —
    /// for `owf inspect --verify` to regenerate the input and prove the
    /// packed reconstruction bit-identical to the in-memory pipeline).
    pub meta: Json,
}

/// What [`pack_store`] wrote.
#[derive(Clone, Debug)]
pub struct PackSummary {
    pub tensors: usize,
    pub elements: usize,
    pub payload_bytes: usize,
    pub file_bytes: usize,
    /// Element-weighted mean of the honest per-tensor bits accounting.
    pub mean_bits: f64,
    /// Realised container rate: 8·payload bytes / elements (includes
    /// scales, codebooks, histograms and overlays, unlike `mean_bits`
    /// which prices scales at their format width and outliers at 32+idx).
    pub packed_bits: f64,
    /// Summed pipeline sq-err across tensors.
    pub sq_err: f64,
    /// Names of store tensors *not* packed (non-f32 or empty) — also
    /// recorded in the manifest so a container serving fewer tensors than
    /// its checkpoint is diagnosable; `owf pack` warns about them.
    pub skipped: Vec<String>,
}

/// Append one section to the payload buffer (64-byte aligned) and return
/// its manifest JSON.
fn push_section(payload: &mut Vec<u8>, bytes: &[u8]) -> Json {
    let pad = (ALIGN - payload.len() % ALIGN) % ALIGN;
    payload.extend(std::iter::repeat(0u8).take(pad));
    let off = payload.len();
    payload.extend_from_slice(bytes);
    Json::obj()
        .push("off", off)
        .push("len", bytes.len())
        .push("fnv", u64_to_hex(fnv1a64(bytes)))
}

fn f32_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn u16_bytes(xs: &[u16]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 2);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn u32_bytes(xs: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn u64_bytes(xs: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 8);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Quantise every f32 tensor of `store` under `opts` and write the `OWQ1`
/// container to `path` atomically.  `fisher_mean` feeds the variable
/// allocator (missing tensors default to 1.0 — a *constant* Fisher shifts
/// every eq.-(5) offset equally and cancels in the bisection, so an empty
/// map degrades gracefully to pure-RMS allocation).
pub fn pack_store(
    store: &Store,
    fisher_mean: &HashMap<String, f64>,
    opts: &PackOptions,
    path: impl AsRef<Path>,
) -> AResult<PackSummary> {
    let base = Scheme::parse(&opts.spec).map_err(|e| {
        ArtifactError::invalid(format!("pack spec {:?}: {e}", opts.spec))
    })?;
    if !(1..=MAX_LANES).contains(&opts.lanes) {
        return Err(ArtifactError::invalid(format!(
            "lane count {} outside 1..={MAX_LANES}",
            opts.lanes
        )));
    }
    let mut skipped: Vec<String> = Vec::new();
    let tensors: Vec<&crate::tensorstore::Tensor> = store
        .tensors
        .iter()
        .filter(|t| {
            let packable = t.dtype == Dtype::F32 && t.numel() > 0;
            if !packable {
                skipped.push(t.name.clone());
            }
            packable
        })
        .collect();
    if tensors.is_empty() {
        return Err(ArtifactError::invalid(
            "store has no non-empty f32 tensors",
        ));
    }

    // --- per-tensor bit widths ------------------------------------------------
    let (alloc_json, bits_per_tensor): (Json, Vec<f64>) = match opts.alloc {
        AllocMode::Flat => {
            let bits = vec![base.bits; tensors.len()];
            let j = Json::obj()
                .push("scheme", "flat")
                .push("target", f64_to_hex(base.bits))
                .push("average", f64_to_hex(base.bits))
                .push(
                    "bits",
                    Json::Arr(
                        bits.iter()
                            .map(|&b| Json::Str(f64_to_hex(b)))
                            .collect(),
                    ),
                );
            (j, bits)
        }
        AllocMode::Variable => {
            let infos: Vec<TensorInfo> = tensors
                .iter()
                .map(|t| TensorInfo {
                    name: t.name.clone(),
                    numel: t.numel(),
                    rms: crate::util::stats::rms(&t.as_f32()),
                    fisher_mean: *fisher_mean
                        .get(&t.name)
                        .unwrap_or(&1.0),
                })
                .collect();
            let alloc = variable_allocation(&infos, base.bits);
            let rounded = round_allocation(&infos, &alloc, base.bits);
            let j = Json::obj()
                .push("scheme", "variable")
                .push("target", f64_to_hex(base.bits))
                .push("average", f64_to_hex(rounded.average))
                .push(
                    "bits",
                    Json::Arr(
                        rounded
                            .bits
                            .iter()
                            .map(|&b| Json::Str(f64_to_hex(b)))
                            .collect(),
                    ),
                );
            (j, rounded.bits)
        }
    };

    // --- encode + serialize ---------------------------------------------------
    let mut payload: Vec<u8> = Vec::new();
    let mut entries: Vec<Json> = Vec::new();
    let mut elements = 0usize;
    let mut bits_weighted = 0f64;
    let mut sq_err = 0f64;
    for (t, &bits) in tensors.iter().zip(&bits_per_tensor) {
        let mut scheme = base.clone();
        scheme.bits = bits;
        let data = t.as_f32();
        // rotation seed: derived from the tensor name, so it is stable
        // across re-packs and needs no coordination with the source
        // (recorded in the manifest iff the tensor was actually rotated)
        let rot_seed = fnv1a64(t.name.as_bytes());
        let et = encode_tensor(
            &scheme,
            &data,
            &t.shape,
            t.channel_axis,
            &[],
            rot_seed,
        )
        .map_err(|e| {
            ArtifactError::invalid(format!("encode {:?}: {e}", t.name))
        })?;

        // alphabet capacity: rANS normalises every seen symbol into a
        // 2^12-slot table and cannot represent more distinct symbols than
        // slots (the coder would panic) — fail typed up front instead
        let seen = et.counts.iter().filter(|&&c| c > 0).count();
        if matches!(opts.codec, Codec::Rans) && seen > RANS_MAX_SYMBOLS {
            return Err(ArtifactError::invalid(format!(
                "tensor {:?}: {seen} distinct symbols exceed the rANS \
                 normalisation capacity of {RANS_MAX_SYMBOLS} — pack \
                 with --codec huffman or raw",
                t.name
            )));
        }

        let indices: &[u16] = match &et.form {
            EncodedForm::Codebook { enc, .. } => &enc.indices,
            EncodedForm::Grid { indices, .. } => indices,
        };
        let coded: Vec<u8> = match opts.codec {
            Codec::Raw => u16_bytes(indices),
            Codec::Huffman => tables::huffman_for(&et.counts)
                .encode_interleaved(indices, opts.lanes),
            Codec::Rans => rans_encode_interleaved(
                &tables::rans_for(&et.counts),
                indices,
                opts.lanes,
            ),
        };
        // grid tensors re-use the codebook section for the dense-slot
        // codepoint table and leave scales empty; the manifest carries
        // the hex-exact δ + slot→bucket map the reader cross-checks the
        // table against
        let (points_bytes, scales_bytes) = match &et.form {
            EncodedForm::Codebook { quantiser, enc } => (
                f32_bytes(quantiser.codebook.points()),
                f32_bytes(&enc.scales),
            ),
            EncodedForm::Grid { points, .. } => {
                (f32_bytes(points), Vec::new())
            }
        };
        let (multiplier, storage_bits) = match &et.form {
            EncodedForm::Codebook { quantiser, .. } => (
                quantiser.scale_multiplier,
                quantiser.codebook.storage_bits(),
            ),
            EncodedForm::Grid { .. } => (scheme.multiplier, 0.0),
        };

        let mut entry = Json::obj()
            .push("name", t.name.as_str())
            .push("shape", t.shape.clone())
            .push("n", t.numel());
        entry = match t.channel_axis {
            Some(ax) => entry.push("channel_axis", ax),
            None => entry.push("channel_axis", Json::Null),
        };
        let mut entry = entry
            .push("spec", scheme.name())
            .push("multiplier", f64_to_hex(multiplier))
            .push("storage_bits", f64_to_hex(storage_bits))
            .push("channel_len", et.channel_len)
            .push("transposed", et.transposed)
            .push("bits", f64_to_hex(et.bits))
            .push("sq_err", f64_to_hex(et.sq_err));
        if let Some(seed) = et.rot_seed {
            entry = entry.push("rot_seed", u64_to_hex(seed));
        }
        if let EncodedForm::Grid { delta, buckets, .. } = &et.form {
            entry = entry.push(
                "grid",
                Json::obj()
                    .push("delta", f64_to_hex(*delta))
                    .push(
                        "buckets",
                        buckets
                            .iter()
                            .map(|&b| b as usize)
                            .collect::<Vec<usize>>(),
                    ),
            );
        }
        let entry = entry.push(
            "sections",
            Json::Obj(vec![
                (
                    "codebook".to_string(),
                    push_section(&mut payload, &points_bytes),
                ),
                (
                    "scales".to_string(),
                    push_section(&mut payload, &scales_bytes),
                ),
                (
                    "payload".to_string(),
                    push_section(&mut payload, &coded),
                ),
                (
                    "counts".to_string(),
                    push_section(&mut payload, &u64_bytes(&et.counts)),
                ),
                (
                    "outlier_idx".to_string(),
                    push_section(&mut payload, &u32_bytes(&et.outlier_idx)),
                ),
                (
                    "outlier_val".to_string(),
                    push_section(&mut payload, &f32_bytes(&et.outlier_val)),
                ),
            ]),
        );
        entries.push(entry);
        elements += t.numel();
        bits_weighted += et.bits * t.numel() as f64;
        sq_err += et.sq_err;
    }

    let manifest = Json::obj()
        .push("kind", "owq-artifact")
        .push("version", VERSION)
        .push("meta", opts.meta.clone())
        .push("spec", opts.spec.as_str())
        .push("codec", opts.codec.name())
        .push("lanes", opts.lanes)
        .push("alloc", alloc_json)
        .push("skipped", skipped.clone())
        .push("tensors", Json::Arr(entries))
        .to_string();

    let mut out =
        Vec::with_capacity(8 + manifest.len() + 8 + payload.len());
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(manifest.len() as u32).to_le_bytes());
    out.extend_from_slice(manifest.as_bytes());
    out.extend_from_slice(&fnv1a64(manifest.as_bytes()).to_le_bytes());
    out.extend_from_slice(&payload);
    crate::util::fsx::atomic_write_io(path.as_ref(), &out).map_err(
        |e| ArtifactError::io(&e, format!("write {:?}", path.as_ref())),
    )?;

    Ok(PackSummary {
        tensors: tensors.len(),
        elements,
        payload_bytes: payload.len(),
        file_bytes: out.len(),
        mean_bits: bits_weighted / elements as f64,
        packed_bits: payload.len() as f64 * 8.0 / elements as f64,
        sq_err,
        skipped,
    })
}
