//! The OWQ pack path (container version 3, magic `OWQ1`): Fisher/RMS bit
//! allocation → the pipeline's fused encode
//! ([`crate::eval::pipeline::encode_tensor`], bit-identical to the
//! in-memory qdq) → K-lane interleaved entropy coding → checksummed
//! sections → crash-safe atomic write (temp file + rename, like
//! [`crate::tensorstore::Store::save`]).
//!
//! [`AllocMode::Fractional`] reaches budgets *between* the integer
//! lattice points: it measures each tensor's (bits, sq-err) candidate
//! curve ([`crate::alloc::frac::measure_points`]), water-fills the
//! Fisher-weighted budget over the lower convex hulls, and packs any
//! genuinely mixed tensor as a v3 `mix` entry — per-part concatenated
//! sections plus a `block_schemes` id stream (one byte per scale block,
//! deterministic in the tensor name, so re-packs are byte-identical).
//!
//! Every scheme the sweep grammar can produce is packable: codebook
//! families persist (codebook, scales, coded indices, histogram, outlier
//! overlay); `grid` schemes persist the dense-slot codepoint table in the
//! codebook section, the entropy-coded dense stream in the payload
//! section and the hex-exact δ + slot→bucket map in the manifest; `:rot`
//! schemes record the per-tensor rotation seed so the reader can re-derive
//! V/W and invert after decode.
//!
//! Failures are typed [`ArtifactError`]s: configuration problems (bad
//! spec, over-capacity rANS alphabet) are `Invalid`, write failures are
//! `Io` with transiency classified from the underlying `ErrorKind` (via
//! [`crate::util::fsx::atomic_write_io`], which preserves it).

use std::collections::HashMap;
use std::path::Path;

use super::{
    f64_to_hex, fnv1a64, u64_to_hex, AResult, ArtifactError, Codec, ALIGN,
    MAGIC, VERSION,
};
use crate::alloc::{
    frac, round_allocation, variable_allocation, TensorInfo,
};
use crate::compress::rans::{rans_encode_interleaved, RANS_MAX_SYMBOLS};
use crate::compress::{tables, MAX_LANES};
use crate::coordinator::config::Scheme;
use crate::eval::pipeline::{
    encode_tensor, encode_tensor_mixed, EncodedForm,
};
use crate::tensorstore::{Dtype, Store};
use crate::util::json::Json;

/// How per-tensor bit widths are chosen.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllocMode {
    /// Every tensor at the spec's bit width.
    Flat,
    /// Eq.-(5) Fisher/RMS variable allocation targeting the spec's bit
    /// width as the model-level average, rounded to integers by the
    /// largest-remainder rule ([`crate::alloc::round_allocation`]).
    Variable,
    /// Fractional-bit allocation: water-filling over each tensor's
    /// measured (bits, sq-err) convex hull, realised by mixing at most
    /// two candidate schemes per tensor at scale-block granularity
    /// ([`crate::alloc::frac`]).  Hits any average in the candidate
    /// range, not just the integer lattice.
    Fractional,
}

impl AllocMode {
    pub fn name(&self) -> &'static str {
        match self {
            AllocMode::Flat => "flat",
            AllocMode::Variable => "variable",
            AllocMode::Fractional => "fractional",
        }
    }

    pub fn parse(s: &str) -> AResult<AllocMode> {
        match s {
            "flat" => Ok(AllocMode::Flat),
            "variable" => Ok(AllocMode::Variable),
            "fractional" => Ok(AllocMode::Fractional),
            other => Err(ArtifactError::invalid(format!(
                "unknown alloc mode {other:?} (flat|variable|fractional)"
            ))),
        }
    }
}

/// Pack configuration.
pub struct PackOptions {
    /// Base scheme spec — any spec the sweep grammar accepts, including
    /// `:rot` and `grid`.
    pub spec: String,
    pub alloc: AllocMode,
    pub codec: Codec,
    /// Interleaved lanes for the entropy-coded payload (ignored by
    /// [`Codec::Raw`]).
    pub lanes: usize,
    /// Target average bits/param for [`AllocMode::Fractional`] —
    /// may be fractional (`--bits 3.3`).  `None` falls back to the
    /// spec's own bit width.  Ignored by the other modes, which target
    /// the spec's width by construction.
    pub target_bits: Option<f64>,
    /// Free-form source description stored in the manifest (`owf pack`
    /// records enough here — sim seed/shapes/dist or checkpoint size —
    /// for `owf inspect --verify` to regenerate the input and prove the
    /// packed reconstruction bit-identical to the in-memory pipeline).
    pub meta: Json,
}

/// What [`pack_store`] wrote.
#[derive(Clone, Debug)]
pub struct PackSummary {
    pub tensors: usize,
    pub elements: usize,
    pub payload_bytes: usize,
    pub file_bytes: usize,
    /// Element-weighted mean of the honest per-tensor bits accounting.
    pub mean_bits: f64,
    /// Realised container rate: 8·payload bytes / elements (includes
    /// scales, codebooks, histograms and overlays, unlike `mean_bits`
    /// which prices scales at their format width and outliers at 32+idx).
    pub packed_bits: f64,
    /// Summed pipeline sq-err across tensors.
    pub sq_err: f64,
    /// Names of store tensors *not* packed (non-f32 or empty) — also
    /// recorded in the manifest so a container serving fewer tensors than
    /// its checkpoint is diagnosable; `owf pack` warns about them.
    pub skipped: Vec<String>,
}

/// Append one section to the payload buffer (64-byte aligned) and return
/// its manifest JSON.
fn push_section(payload: &mut Vec<u8>, bytes: &[u8]) -> Json {
    let pad = (ALIGN - payload.len() % ALIGN) % ALIGN;
    payload.extend(std::iter::repeat(0u8).take(pad));
    let off = payload.len();
    payload.extend_from_slice(bytes);
    Json::obj()
        .push("off", off)
        .push("len", bytes.len())
        .push("fnv", u64_to_hex(fnv1a64(bytes)))
}

fn f32_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn u16_bytes(xs: &[u16]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 2);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn u32_bytes(xs: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn u64_bytes(xs: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 8);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// What the allocator decided for one tensor: a single scheme (the
/// Flat/Variable modes, and Fractional picks that land on a hull
/// vertex or whose realised block assignment degenerates to one side),
/// or a genuine two-scheme mix with its per-block id stream.
enum Plan {
    Single(Scheme),
    Mixed { schemes: Vec<Scheme>, assign: Vec<u8> },
}

/// Quantise every f32 tensor of `store` under `opts` and write the `OWQ1`
/// container to `path` atomically.  `fisher_mean` feeds the variable
/// allocator (missing tensors default to 1.0 — a *constant* Fisher shifts
/// every eq.-(5) offset equally and cancels in the bisection, so an empty
/// map degrades gracefully to pure-RMS allocation).
pub fn pack_store(
    store: &Store,
    fisher_mean: &HashMap<String, f64>,
    opts: &PackOptions,
    path: impl AsRef<Path>,
) -> AResult<PackSummary> {
    let base = Scheme::parse(&opts.spec).map_err(|e| {
        ArtifactError::invalid(format!("pack spec {:?}: {e}", opts.spec))
    })?;
    if !(1..=MAX_LANES).contains(&opts.lanes) {
        return Err(ArtifactError::invalid(format!(
            "lane count {} outside 1..={MAX_LANES}",
            opts.lanes
        )));
    }
    let mut skipped: Vec<String> = Vec::new();
    let tensors: Vec<&crate::tensorstore::Tensor> = store
        .tensors
        .iter()
        .filter(|t| {
            let packable = t.dtype == Dtype::F32 && t.numel() > 0;
            if !packable {
                skipped.push(t.name.clone());
            }
            packable
        })
        .collect();
    if tensors.is_empty() {
        return Err(ArtifactError::invalid(
            "store has no non-empty f32 tensors",
        ));
    }

    // --- per-tensor plans -----------------------------------------------------
    let single_plans = |bits: &[f64]| -> Vec<Plan> {
        bits.iter()
            .map(|&b| {
                let mut s = base.clone();
                s.bits = b;
                Plan::Single(s)
            })
            .collect()
    };
    let (alloc_json, plans): (Json, Vec<Plan>) = match opts.alloc {
        AllocMode::Flat => {
            let bits = vec![base.bits; tensors.len()];
            let j = Json::obj()
                .push("scheme", "flat")
                .push("target", f64_to_hex(base.bits))
                .push("average", f64_to_hex(base.bits))
                .push(
                    "bits",
                    Json::Arr(
                        bits.iter()
                            .map(|&b| Json::Str(f64_to_hex(b)))
                            .collect(),
                    ),
                );
            (j, single_plans(&bits))
        }
        AllocMode::Variable => {
            let infos: Vec<TensorInfo> = tensors
                .iter()
                .map(|t| TensorInfo {
                    name: t.name.clone(),
                    numel: t.numel(),
                    rms: crate::util::stats::rms(&t.as_f32()),
                    fisher_mean: *fisher_mean
                        .get(&t.name)
                        .unwrap_or(&1.0),
                })
                .collect();
            let alloc = variable_allocation(&infos, base.bits);
            let rounded = round_allocation(&infos, &alloc, base.bits);
            let j = Json::obj()
                .push("scheme", "variable")
                .push("target", f64_to_hex(base.bits))
                .push("average", f64_to_hex(rounded.average))
                .push(
                    "bits",
                    Json::Arr(
                        rounded
                            .bits
                            .iter()
                            .map(|&b| Json::Str(f64_to_hex(b)))
                            .collect(),
                    ),
                );
            (j, single_plans(&rounded.bits))
        }
        AllocMode::Fractional => {
            frac::validate_base(&base).map_err(|e| {
                ArtifactError::invalid(format!(
                    "fractional alloc over {:?}: {e}",
                    opts.spec
                ))
            })?;
            let target = opts.target_bits.unwrap_or(base.bits);
            let candidates = frac::candidate_schemes(&base);
            let mut curves: Vec<frac::TensorCurve> =
                Vec::with_capacity(tensors.len());
            for t in &tensors {
                let points = frac::measure_points(
                    &base,
                    &t.as_f32(),
                    &t.shape,
                    t.channel_axis,
                    &[],
                    fnv1a64(t.name.as_bytes()),
                )
                .map_err(|e| {
                    ArtifactError::invalid(format!(
                        "measure {:?}: {e}",
                        t.name
                    ))
                })?;
                curves.push(frac::TensorCurve::new(
                    t.name.clone(),
                    t.numel(),
                    *fisher_mean.get(&t.name).unwrap_or(&1.0),
                    points,
                ));
            }
            let alloc = frac::waterfill(&curves, target);
            let mut plans = Vec::with_capacity(tensors.len());
            for (t, choice) in tensors.iter().zip(&alloc.choices) {
                let plan = if choice.is_pure() {
                    Plan::Single(candidates[choice.lo].clone())
                } else {
                    let lens: Vec<usize> = crate::scaling::scale_groups(
                        t.numel(),
                        base.granularity,
                        0,
                    )
                    .iter()
                    .map(|&(_, len)| len)
                    .collect();
                    let hi_elems = (choice.hi_weight * t.numel() as f64)
                        .round() as usize;
                    let assign = frac::assign_blocks(
                        fnv1a64(t.name.as_bytes()),
                        &lens,
                        hi_elems,
                    );
                    // rounding can realise the whole tensor on one side
                    // — that's a pure tensor, not a degenerate mix
                    if assign.iter().all(|&a| a == 0) {
                        Plan::Single(candidates[choice.lo].clone())
                    } else if assign.iter().all(|&a| a == 1) {
                        Plan::Single(candidates[choice.hi].clone())
                    } else {
                        Plan::Mixed {
                            schemes: vec![
                                candidates[choice.lo].clone(),
                                candidates[choice.hi].clone(),
                            ],
                            assign,
                        }
                    }
                };
                plans.push(plan);
            }
            let j = Json::obj()
                .push("scheme", "fractional")
                .push("target", f64_to_hex(target))
                .push("average", f64_to_hex(alloc.average))
                .push(
                    "bits",
                    Json::Arr(
                        alloc
                            .choices
                            .iter()
                            .map(|c| Json::Str(f64_to_hex(c.bits)))
                            .collect(),
                    ),
                );
            (j, plans)
        }
    };

    // --- encode + serialize ---------------------------------------------------
    let mut payload: Vec<u8> = Vec::new();
    let mut entries: Vec<Json> = Vec::new();
    let mut elements = 0usize;
    let mut bits_weighted = 0f64;
    let mut sq_err = 0f64;
    for (t, plan) in tensors.iter().zip(&plans) {
        let data = t.as_f32();
        // rotation seed: derived from the tensor name, so it is stable
        // across re-packs and needs no coordination with the source
        // (recorded in the manifest iff the tensor was actually rotated)
        let rot_seed = fnv1a64(t.name.as_bytes());

        let mut entry = Json::obj()
            .push("name", t.name.as_str())
            .push("shape", t.shape.clone())
            .push("n", t.numel());
        entry = match t.channel_axis {
            Some(ax) => entry.push("channel_axis", ax),
            None => entry.push("channel_axis", Json::Null),
        };

        let (entry, t_bits, t_err) = match plan {
            Plan::Single(scheme) => {
                let et = encode_tensor(
                    scheme,
                    &data,
                    &t.shape,
                    t.channel_axis,
                    &[],
                    rot_seed,
                )
                .map_err(|e| {
                    ArtifactError::invalid(format!(
                        "encode {:?}: {e}",
                        t.name
                    ))
                })?;

                // alphabet capacity: rANS normalises every seen symbol
                // into a 2^12-slot table and cannot represent more
                // distinct symbols than slots (the coder would panic) —
                // fail typed up front instead
                let seen =
                    et.counts.iter().filter(|&&c| c > 0).count();
                if matches!(opts.codec, Codec::Rans)
                    && seen > RANS_MAX_SYMBOLS
                {
                    return Err(ArtifactError::invalid(format!(
                        "tensor {:?}: {seen} distinct symbols exceed \
                         the rANS normalisation capacity of \
                         {RANS_MAX_SYMBOLS} — pack with --codec \
                         huffman or raw",
                        t.name
                    )));
                }

                let indices: &[u16] = match &et.form {
                    EncodedForm::Codebook { enc, .. } => &enc.indices,
                    EncodedForm::Grid { indices, .. } => indices,
                    EncodedForm::Mixed { .. } => unreachable!(
                        "encode_tensor never returns Mixed"
                    ),
                };
                let coded: Vec<u8> = match opts.codec {
                    Codec::Raw => u16_bytes(indices),
                    Codec::Huffman => tables::huffman_for(&et.counts)
                        .encode_interleaved(indices, opts.lanes),
                    Codec::Rans => rans_encode_interleaved(
                        &tables::rans_for(&et.counts),
                        indices,
                        opts.lanes,
                    ),
                };
                // grid tensors re-use the codebook section for the
                // dense-slot codepoint table and leave scales empty;
                // the manifest carries the hex-exact δ + slot→bucket
                // map the reader cross-checks the table against
                let (points_bytes, scales_bytes) = match &et.form {
                    EncodedForm::Codebook { quantiser, enc } => (
                        f32_bytes(quantiser.codebook.points()),
                        f32_bytes(&enc.scales),
                    ),
                    EncodedForm::Grid { points, .. } => {
                        (f32_bytes(points), Vec::new())
                    }
                    EncodedForm::Mixed { .. } => unreachable!(),
                };
                let (multiplier, storage_bits) = match &et.form {
                    EncodedForm::Codebook { quantiser, .. } => (
                        quantiser.scale_multiplier,
                        quantiser.codebook.storage_bits(),
                    ),
                    EncodedForm::Grid { .. } => (scheme.multiplier, 0.0),
                    EncodedForm::Mixed { .. } => unreachable!(),
                };

                let mut entry = entry
                    .push("spec", scheme.name())
                    .push("multiplier", f64_to_hex(multiplier))
                    .push("storage_bits", f64_to_hex(storage_bits))
                    .push("channel_len", et.channel_len)
                    .push("transposed", et.transposed)
                    .push("bits", f64_to_hex(et.bits))
                    .push("sq_err", f64_to_hex(et.sq_err));
                if let Some(seed) = et.rot_seed {
                    entry = entry.push("rot_seed", u64_to_hex(seed));
                }
                if let EncodedForm::Grid { delta, buckets, .. } =
                    &et.form
                {
                    entry = entry.push(
                        "grid",
                        Json::obj()
                            .push("delta", f64_to_hex(*delta))
                            .push(
                                "buckets",
                                buckets
                                    .iter()
                                    .map(|&b| b as usize)
                                    .collect::<Vec<usize>>(),
                            ),
                    );
                }
                let entry = entry.push(
                    "sections",
                    Json::Obj(vec![
                        (
                            "codebook".to_string(),
                            push_section(&mut payload, &points_bytes),
                        ),
                        (
                            "scales".to_string(),
                            push_section(&mut payload, &scales_bytes),
                        ),
                        (
                            "payload".to_string(),
                            push_section(&mut payload, &coded),
                        ),
                        (
                            "counts".to_string(),
                            push_section(
                                &mut payload,
                                &u64_bytes(&et.counts),
                            ),
                        ),
                        (
                            "outlier_idx".to_string(),
                            push_section(
                                &mut payload,
                                &u32_bytes(&et.outlier_idx),
                            ),
                        ),
                        (
                            "outlier_val".to_string(),
                            push_section(
                                &mut payload,
                                &f32_bytes(&et.outlier_val),
                            ),
                        ),
                    ]),
                );
                (entry, et.bits, et.sq_err)
            }
            Plan::Mixed { schemes, assign } => {
                let et = encode_tensor_mixed(
                    schemes,
                    assign,
                    &data,
                    &t.shape,
                    t.channel_axis,
                    &[],
                    rot_seed,
                )
                .map_err(|e| {
                    ArtifactError::invalid(format!(
                        "encode mixed {:?}: {e}",
                        t.name
                    ))
                })?;
                let parts = match &et.form {
                    EncodedForm::Mixed { parts, .. } => parts,
                    _ => unreachable!(
                        "encode_tensor_mixed always returns Mixed"
                    ),
                };

                // per-part concatenated sections: each partition is a
                // self-contained codebook stream with its own entropy
                // model, so the capacity guard is per part too
                let mut points_bytes: Vec<u8> = Vec::new();
                let mut scales_bytes: Vec<u8> = Vec::new();
                let mut coded: Vec<u8> = Vec::new();
                let mut counts_all: Vec<u64> = Vec::new();
                let mut specs: Vec<Json> = Vec::new();
                let mut multipliers: Vec<Json> = Vec::new();
                let mut storage_bits: Vec<Json> = Vec::new();
                let mut points_len: Vec<usize> = Vec::new();
                let mut payload_len: Vec<usize> = Vec::new();
                let mut part_elems: Vec<usize> = Vec::new();
                for part in parts {
                    let seen =
                        part.counts.iter().filter(|&&c| c > 0).count();
                    if matches!(opts.codec, Codec::Rans)
                        && seen > RANS_MAX_SYMBOLS
                    {
                        return Err(ArtifactError::invalid(format!(
                            "tensor {:?} part {}: {seen} distinct \
                             symbols exceed the rANS normalisation \
                             capacity of {RANS_MAX_SYMBOLS} — pack \
                             with --codec huffman or raw",
                            t.name,
                            part.scheme.name()
                        )));
                    }
                    let part_coded: Vec<u8> = match opts.codec {
                        Codec::Raw => u16_bytes(&part.enc.indices),
                        Codec::Huffman => {
                            tables::huffman_for(&part.counts)
                                .encode_interleaved(
                                    &part.enc.indices,
                                    opts.lanes,
                                )
                        }
                        Codec::Rans => rans_encode_interleaved(
                            &tables::rans_for(&part.counts),
                            &part.enc.indices,
                            opts.lanes,
                        ),
                    };
                    specs.push(Json::Str(part.scheme.name()));
                    multipliers.push(Json::Str(f64_to_hex(
                        part.quantiser.scale_multiplier,
                    )));
                    storage_bits.push(Json::Str(f64_to_hex(
                        part.quantiser.codebook.storage_bits(),
                    )));
                    points_len
                        .push(part.quantiser.codebook.points().len());
                    payload_len.push(part_coded.len());
                    part_elems.push(part.n);
                    points_bytes.extend_from_slice(&f32_bytes(
                        part.quantiser.codebook.points(),
                    ));
                    scales_bytes
                        .extend_from_slice(&f32_bytes(&part.enc.scales));
                    coded.extend_from_slice(&part_coded);
                    counts_all.extend_from_slice(&part.counts);
                }

                // the top-level spec records the realised fractional
                // rate; multiplier/storage_bits live per part in `mix`,
                // so the top-level slots are explicit NaNs
                let mut spec = base.clone();
                spec.bits = et.bits;
                let mut entry = entry
                    .push("spec", spec.name())
                    .push("multiplier", f64_to_hex(f64::NAN))
                    .push("storage_bits", f64_to_hex(f64::NAN))
                    .push("channel_len", et.channel_len)
                    .push("transposed", et.transposed)
                    .push("bits", f64_to_hex(et.bits))
                    .push("sq_err", f64_to_hex(et.sq_err))
                    .push(
                        "mix",
                        Json::obj()
                            .push("specs", Json::Arr(specs))
                            .push(
                                "multipliers",
                                Json::Arr(multipliers),
                            )
                            .push(
                                "storage_bits",
                                Json::Arr(storage_bits),
                            )
                            .push("points_len", points_len)
                            .push("payload_len", payload_len)
                            .push("part_elems", part_elems),
                    );
                if let Some(seed) = et.rot_seed {
                    entry = entry.push("rot_seed", u64_to_hex(seed));
                }
                let entry = entry.push(
                    "sections",
                    Json::Obj(vec![
                        (
                            "codebook".to_string(),
                            push_section(&mut payload, &points_bytes),
                        ),
                        (
                            "scales".to_string(),
                            push_section(&mut payload, &scales_bytes),
                        ),
                        (
                            "payload".to_string(),
                            push_section(&mut payload, &coded),
                        ),
                        (
                            "counts".to_string(),
                            push_section(
                                &mut payload,
                                &u64_bytes(&counts_all),
                            ),
                        ),
                        (
                            "outlier_idx".to_string(),
                            push_section(&mut payload, &[]),
                        ),
                        (
                            "outlier_val".to_string(),
                            push_section(&mut payload, &[]),
                        ),
                        (
                            "block_schemes".to_string(),
                            push_section(&mut payload, assign),
                        ),
                    ]),
                );
                (entry, et.bits, et.sq_err)
            }
        };
        entries.push(entry);
        elements += t.numel();
        bits_weighted += t_bits * t.numel() as f64;
        sq_err += t_err;
    }

    let manifest = Json::obj()
        .push("kind", "owq-artifact")
        .push("version", VERSION)
        .push("meta", opts.meta.clone())
        .push("spec", opts.spec.as_str())
        .push("codec", opts.codec.name())
        .push("lanes", opts.lanes)
        .push("alloc", alloc_json)
        .push("skipped", skipped.clone())
        .push("tensors", Json::Arr(entries))
        .to_string();

    let mut out =
        Vec::with_capacity(8 + manifest.len() + 8 + payload.len());
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(manifest.len() as u32).to_le_bytes());
    out.extend_from_slice(manifest.as_bytes());
    out.extend_from_slice(&fnv1a64(manifest.as_bytes()).to_le_bytes());
    out.extend_from_slice(&payload);
    crate::util::fsx::atomic_write_io(path.as_ref(), &out).map_err(
        |e| ArtifactError::io(&e, format!("write {:?}", path.as_ref())),
    )?;

    Ok(PackSummary {
        tensors: tensors.len(),
        elements,
        payload_bytes: payload.len(),
        file_bytes: out.len(),
        mean_bits: bits_weighted / elements as f64,
        packed_bits: payload.len() as f64 * 8.0 / elements as f64,
        sq_err,
        skipped,
    })
}
