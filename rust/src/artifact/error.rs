//! Structured error taxonomy for the artifact layer.
//!
//! The serving path needs to *dispatch* on failure, not just print it:
//! transient I/O is retried with backoff, corruption is quarantined (never
//! retried — re-reading flipped bits does not unflip them), truncation is
//! reported as a torn container, and an overloaded server sheds load with a
//! typed rejection the caller can turn into backpressure.  `ArtifactError`
//! is `Clone` so a single decode failure can be shared verbatim with every
//! coalesced waiter and stored in the quarantine map as the poison cause.
//!
//! The type implements `std::error::Error`, so existing `anyhow` call sites
//! keep working unchanged via the blanket `From` conversion.

use std::fmt;

/// Typed failure for artifact open/read/decode/serve operations.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ArtifactError {
    /// Stored bytes fail validation: a checksum mismatch, an impossible
    /// index, or a decoder panic contained at the artifact boundary.
    /// `tensor` is empty for container-level damage (e.g. the manifest).
    Corrupt {
        tensor: String,
        section: String,
        detail: String,
    },
    /// The container is structurally incomplete: bad magic, or bytes
    /// missing relative to what the header/manifest promise (truncation,
    /// a partial non-atomic write).
    TornContainer { detail: String },
    /// An I/O failure reading or writing container bytes. `transient`
    /// failures (EINTR/EAGAIN/timeouts) are safe to retry; permanent ones
    /// (ENOENT, ENOSPC, EACCES) are not.
    Io { transient: bool, detail: String },
    /// The named tensor does not exist in the artifact manifest.
    NotFound { tensor: String },
    /// The server's admission gate rejected the request: `limit` decodes
    /// were already in flight and no queueing was configured
    /// (`queue_depth == 0`).
    Overloaded { limit: usize },
    /// The decode wait queue was at capacity when the request arrived:
    /// `depth` requests were already queued behind the busy permits.
    QueueFull { depth: usize },
    /// The request's deadline passed before it could be served — while
    /// queued for a decode permit or while waiting on a coalesced decode.
    /// `waited_ms` is how long the request actually waited.
    DeadlineExceeded { tensor: String, waited_ms: u64 },
    /// The tensor's circuit breaker is open: it repeatedly blew the slow-
    /// decode budget, so new cold decodes are shed until a half-open
    /// probe succeeds.  Cached copies keep serving.
    BreakerOpen { tensor: String },
    /// The tensor was previously found corrupt and is poisoned; `cause`
    /// is the original failure. Requests fail fast without re-decoding.
    Quarantined {
        tensor: String,
        cause: Box<ArtifactError>,
    },
    /// A usage or configuration error (bad spec, wrong buffer length,
    /// unsupported version) — the container bytes themselves are fine.
    Invalid { detail: String },
}

impl ArtifactError {
    pub fn corrupt(
        tensor: &str,
        section: &str,
        detail: impl fmt::Display,
    ) -> ArtifactError {
        ArtifactError::Corrupt {
            tensor: tensor.to_string(),
            section: section.to_string(),
            detail: detail.to_string(),
        }
    }

    pub fn torn(detail: impl fmt::Display) -> ArtifactError {
        ArtifactError::TornContainer {
            detail: detail.to_string(),
        }
    }

    pub fn invalid(detail: impl fmt::Display) -> ArtifactError {
        ArtifactError::Invalid {
            detail: detail.to_string(),
        }
    }

    /// Classify a raw `io::Error` (retryability decided by its kind).
    pub fn io(err: &std::io::Error, what: impl fmt::Display) -> ArtifactError {
        ArtifactError::Io {
            transient: is_transient_kind(err.kind()),
            detail: format!("{what}: {err}"),
        }
    }

    /// True only for `Io { transient: true }` — the sole retryable class.
    pub fn is_transient_io(&self) -> bool {
        matches!(self, ArtifactError::Io { transient: true, .. })
    }

    /// True for damage that should poison the tensor in the quarantine
    /// map: validated-bytes corruption, never I/O or load shedding.
    pub fn is_corrupt(&self) -> bool {
        matches!(self, ArtifactError::Corrupt { .. })
    }

    /// Short class name for stats lines and fsck verdict tables.
    pub fn kind_name(&self) -> &'static str {
        match self {
            ArtifactError::Corrupt { .. } => "corrupt",
            ArtifactError::TornContainer { .. } => "torn",
            ArtifactError::Io { transient: true, .. } => "io-transient",
            ArtifactError::Io { transient: false, .. } => "io",
            ArtifactError::NotFound { .. } => "not-found",
            ArtifactError::Overloaded { .. } => "overloaded",
            ArtifactError::QueueFull { .. } => "queue-full",
            ArtifactError::DeadlineExceeded { .. } => "deadline",
            ArtifactError::BreakerOpen { .. } => "breaker-open",
            ArtifactError::Quarantined { .. } => "quarantined",
            ArtifactError::Invalid { .. } => "invalid",
        }
    }
}

/// Retry is safe only when the failure is environmental and momentary.
/// Everything else (missing file, full disk, permissions) will fail the
/// same way again, so retrying just adds latency before the same error.
pub fn is_transient_kind(kind: std::io::ErrorKind) -> bool {
    matches!(
        kind,
        std::io::ErrorKind::Interrupted
            | std::io::ErrorKind::WouldBlock
            | std::io::ErrorKind::TimedOut
    )
}

impl fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArtifactError::Corrupt {
                tensor,
                section,
                detail,
            } => {
                if tensor.is_empty() {
                    write!(f, "corrupt container: section {section}: {detail}")
                } else {
                    write!(
                        f,
                        "corrupt artifact: tensor {tensor:?} section \
                         {section}: {detail}"
                    )
                }
            }
            ArtifactError::TornContainer { detail } => {
                write!(f, "torn container: {detail}")
            }
            ArtifactError::Io { transient, detail } => {
                let class = if *transient { "transient" } else { "permanent" };
                write!(f, "i/o error ({class}): {detail}")
            }
            ArtifactError::NotFound { tensor } => {
                write!(f, "tensor {tensor:?} not found in artifact")
            }
            ArtifactError::Overloaded { limit } => {
                write!(
                    f,
                    "server overloaded: {limit} concurrent decodes already \
                     in flight"
                )
            }
            ArtifactError::QueueFull { depth } => {
                write!(
                    f,
                    "server overloaded: decode queue full ({depth} \
                     requests already waiting)"
                )
            }
            ArtifactError::DeadlineExceeded { tensor, waited_ms } => {
                write!(
                    f,
                    "deadline exceeded for tensor {tensor:?} after \
                     waiting {waited_ms}ms"
                )
            }
            ArtifactError::BreakerOpen { tensor } => {
                write!(
                    f,
                    "tensor {tensor:?} circuit breaker open (repeatedly \
                     exceeded the slow-decode budget); cold decodes shed \
                     until a probe succeeds"
                )
            }
            ArtifactError::Quarantined { tensor, cause } => {
                write!(f, "tensor {tensor:?} quarantined: {cause}")
            }
            ArtifactError::Invalid { detail } => write!(f, "{detail}"),
        }
    }
}

impl std::error::Error for ArtifactError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ArtifactError::Quarantined { cause, .. } => Some(cause.as_ref()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transient_classification() {
        let eintr = std::io::Error::new(
            std::io::ErrorKind::Interrupted,
            "injected",
        );
        let enoent = std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "missing",
        );
        assert!(ArtifactError::io(&eintr, "read").is_transient_io());
        assert!(!ArtifactError::io(&enoent, "read").is_transient_io());
    }

    #[test]
    fn corrupt_classification_and_display() {
        let e = ArtifactError::corrupt("w0", "payload", "checksum mismatch");
        assert!(e.is_corrupt());
        assert!(!e.is_transient_io());
        let msg = e.to_string();
        assert!(msg.contains("corrupt"), "{msg}");
        assert!(msg.contains("w0"), "{msg}");
        assert!(msg.contains("payload"), "{msg}");
        // container-level corruption omits the tensor
        let m = ArtifactError::corrupt("", "manifest", "bad fnv").to_string();
        assert!(m.contains("manifest"), "{m}");
    }

    #[test]
    fn backpressure_variants_classify_and_display() {
        let q = ArtifactError::QueueFull { depth: 8 };
        assert_eq!(q.kind_name(), "queue-full");
        assert!(!q.is_corrupt() && !q.is_transient_io());
        assert!(q.to_string().contains('8'), "{q}");
        let d = ArtifactError::DeadlineExceeded {
            tensor: "w0".into(),
            waited_ms: 125,
        };
        assert_eq!(d.kind_name(), "deadline");
        assert!(!d.is_corrupt());
        let msg = d.to_string();
        assert!(msg.contains("w0") && msg.contains("125"), "{msg}");
        let b = ArtifactError::BreakerOpen { tensor: "w1".into() };
        assert_eq!(b.kind_name(), "breaker-open");
        assert!(!b.is_corrupt());
        assert!(b.to_string().contains("w1"), "{b}");
    }

    #[test]
    fn quarantine_preserves_cause_via_source() {
        use std::error::Error as _;
        let cause = ArtifactError::corrupt("a", "scales", "bit flip");
        let q = ArtifactError::Quarantined {
            tensor: "a".into(),
            cause: Box::new(cause.clone()),
        };
        let src = q.source().expect("quarantine must expose its cause");
        assert_eq!(src.to_string(), cause.to_string());
        assert!(q.to_string().contains("quarantined"));
    }

    #[test]
    fn converts_into_anyhow_at_existing_call_sites() {
        fn speaks_anyhow() -> anyhow::Result<()> {
            Err(ArtifactError::torn("short read"))?
        }
        let err = speaks_anyhow().unwrap_err();
        assert!(err.to_string().contains("torn container"));
    }
}
