//! Fractional-bit allocation by scheme mixing (Q-Palette, PAPERS.md
//! arxiv 2509.20214).
//!
//! The eq.-5 allocator in [`super`] picks one integer-family scheme per
//! tensor, so reachable model budgets sit on the scheme lattice.  This
//! module reaches the continuum: for each tensor it *measures* the
//! (effective-bits, sq-err) operating point of every candidate scheme on
//! the tensor's own data (StatQAT's point — measured, not assumed), takes
//! the lower convex hull of those points, and water-fills the
//! Fisher-weighted budget over the hull segments.  The output is a
//! per-tensor [`MixChoice`]: at most two hull-adjacent schemes whose
//! element-weighted average hits any target in the hull's bits range.
//! A mix is realised *within* the tensor by assigning whole scale blocks
//! to one scheme or the other — deterministically, seeded by the tensor
//! name — so re-packing the same store is byte-identical.
//!
//! Optimality: the budget problem is a linear program over per-tensor
//! hull mixtures.  Hull segments are convex (strictly decreasing
//! error-per-bit gain within a tensor), so taking segments globally in
//! decreasing `f̄_t·Δerr / (N_t·Δbits)` order and splitting only the
//! marginal segment *is* the LP optimum — in particular it is never worse
//! than the best single-scheme allocation at the same budget (asserted in
//! the tests below, on measured points).

use anyhow::{bail, Result};

use crate::compress::entropy_bits;
use crate::coordinator::config::{Element, Scheme};
use crate::eval::pipeline::{build_quantiser, prepare_layout, rotation_pair};
use crate::quant::rotation::rotate_2d;
use crate::scaling::Granularity;

/// The candidate lattice: integer bit widths the mixer interpolates
/// between.  These bounds are what "formats exist for 2..=8 bits" means
/// concretely — [`super::MIN_BITS`]/[`super::MAX_BITS`] are derived from
/// them, and [`super::bits_bounds`] derives the clamp range for any other
/// candidate set.
pub const CANDIDATE_MIN_BITS: u32 = 2;
pub const CANDIDATE_MAX_BITS: u32 = 8;

/// The candidate schemes for a base spec: the base with its bit width
/// swept over the integer lattice, everything else (granularity,
/// statistic, flags) unchanged.  Order matters: point index `i` in a
/// measured curve is candidate index `i`.
pub fn candidate_schemes(base: &Scheme) -> Vec<Scheme> {
    (CANDIDATE_MIN_BITS..=CANDIDATE_MAX_BITS)
        .map(|k| {
            let mut s = base.clone();
            s.bits = k as f64;
            s
        })
        .collect()
}

/// Check a base scheme is mixable and return its block length.
///
/// Mixing assigns whole scale blocks to schemes, so the base must use
/// block granularity; sparse overlays select outliers over the whole
/// tensor (the partitions would disagree about which elements are gone),
/// and grid schemes have no per-block boundary in their entropy-coded
/// stream — both are rejected typed.  `:compress`, `:rot` and `:search`
/// are all fine.
pub fn validate_base(base: &Scheme) -> Result<usize> {
    let block = match base.granularity {
        Granularity::Block(b) => b,
        g => bail!(
            "fractional allocation mixes schemes per scale block; \
             {g:?} granularity has no block boundary to assign on"
        ),
    };
    if base.element == Element::Grid {
        bail!(
            "fractional allocation needs codebook schemes \
             (grid rates are entropy-determined, not mixable per block)"
        );
    }
    if base.sparse > 0.0 {
        bail!(
            "fractional allocation does not support :sparse \
             (outlier selection is whole-tensor; block partitions \
             would disagree)"
        );
    }
    Ok(block)
}

/// One measured operating point: a concrete candidate spec, its honest
/// effective bits per element (entropy rate when `:compress`) and the
/// squared error it achieves on the tensor's data.
#[derive(Clone, Debug)]
pub struct SchemePoint {
    pub spec: String,
    pub bits: f64,
    pub sq_err: f64,
}

/// Measure the candidate lattice on one tensor: rotate/lay out once (the
/// exact basis decision `encode_tensor` makes for the same seed), then
/// run each candidate through [`build_quantiser`] + `encode_with_stats`
/// and record the same bits expression the writer persists.  The sq-err
/// is measured in the laid-out (rotated) basis; rotations are orthogonal,
/// so ranking and water-filling over these points matches the
/// original-basis objective.
pub fn measure_points(
    base: &Scheme,
    data: &[f32],
    shape: &[usize],
    channel_axis: Option<usize>,
    fisher: &[f32],
    seed: u64,
) -> Result<Vec<SchemePoint>> {
    validate_base(base)?;
    let mut work = data.to_vec();
    if base.rotate && shape.len() == 2 {
        let (rows, cols) = (shape[0], shape[1]);
        let (v, w) = rotation_pair(rows, cols, seed);
        rotate_2d(&mut work, rows, cols, &v, &w);
    }
    let (flat, channel_len, _transposed) =
        prepare_layout(work, shape, channel_axis, base.granularity);
    let n = flat.len();
    let mut points = Vec::with_capacity(
        (CANDIDATE_MAX_BITS - CANDIDATE_MIN_BITS + 1) as usize,
    );
    for scheme in candidate_schemes(base) {
        let quantiser = build_quantiser(&scheme, &flat, channel_len, fisher)?;
        let (_enc, stats) = quantiser.encode_with_stats(&flat, channel_len);
        let mut bits = quantiser.bits_per_element(n, channel_len);
        if scheme.compress {
            bits = bits - quantiser.codebook.storage_bits()
                + entropy_bits(&stats.counts);
        }
        points.push(SchemePoint {
            spec: scheme.name(),
            bits,
            sq_err: stats.sq_err,
        });
    }
    Ok(points)
}

/// Indices into a point set forming its lower convex hull, bits strictly
/// increasing and sq-err strictly decreasing left to right.
///
/// Degeneracies: equal-bits points keep only the lowest-error one;
/// collinear middles are dropped (a mix of the endpoints realises them
/// anyway); a trailing stretch where more bits don't reduce error is
/// pruned, so spending past the elbow is never chosen.  A single point —
/// or a set where one point dominates all others — yields a singleton
/// hull, which water-filling handles as a pure (unmixable) tensor.
pub fn lower_hull(points: &[SchemePoint]) -> Vec<usize> {
    assert!(!points.is_empty());
    let mut order: Vec<usize> = (0..points.len()).collect();
    order.sort_by(|&a, &b| {
        points[a]
            .bits
            .partial_cmp(&points[b].bits)
            .unwrap()
            .then(points[a].sq_err.partial_cmp(&points[b].sq_err).unwrap())
            .then(a.cmp(&b))
    });
    order.dedup_by(|cur, prev| points[*cur].bits == points[*prev].bits);

    // monotone chain: keep a middle point only if it sits strictly below
    // the chord of its neighbours
    let mut hull: Vec<usize> = Vec::new();
    for &i in &order {
        while hull.len() >= 2 {
            let a = &points[hull[hull.len() - 2]];
            let b = &points[hull[hull.len() - 1]];
            let c = &points[i];
            let cross = (b.bits - a.bits) * (c.sq_err - a.sq_err)
                - (b.sq_err - a.sq_err) * (c.bits - a.bits);
            if cross <= 0.0 {
                hull.pop();
            } else {
                break;
            }
        }
        hull.push(i);
    }
    // prune the flat/rising tail: more bits must mean strictly less error
    while hull.len() >= 2 {
        let last = &points[hull[hull.len() - 1]];
        let prev = &points[hull[hull.len() - 2]];
        if last.sq_err >= prev.sq_err {
            hull.pop();
        } else {
            break;
        }
    }
    hull
}

/// One tensor's measured rate–distortion curve plus its hull.
#[derive(Clone, Debug)]
pub struct TensorCurve {
    pub name: String,
    pub numel: usize,
    /// Fisher-diagonal mean f̄_t — the weight on this tensor's sq-err in
    /// the model-level objective (1.0 when no estimate exists, matching
    /// the eq.-5 allocator's degradation).
    pub fisher: f64,
    pub points: Vec<SchemePoint>,
    /// Indices into `points`: the lower convex hull (see [`lower_hull`]).
    pub hull: Vec<usize>,
}

impl TensorCurve {
    pub fn new(
        name: impl Into<String>,
        numel: usize,
        fisher: f64,
        points: Vec<SchemePoint>,
    ) -> TensorCurve {
        let hull = lower_hull(&points);
        TensorCurve {
            name: name.into(),
            numel,
            fisher,
            points,
            hull,
        }
    }
}

/// What one tensor gets: a pure scheme (`lo == hi`) or a two-scheme mix.
/// `lo`/`hi` index the curve's `points` (equivalently the candidate set),
/// with `hi` the higher-bits endpoint of one hull segment.
#[derive(Clone, Copy, Debug)]
pub struct MixChoice {
    pub lo: usize,
    pub hi: usize,
    /// Fraction of elements under `hi`, in [0, 1); 0 ⇒ pure `lo`.
    pub hi_weight: f64,
    /// Realised effective bits: hull-interpolated between the endpoints.
    pub bits: f64,
    /// Realised sq-err: the same interpolation (mixing is linear in both).
    pub sq_err: f64,
}

impl MixChoice {
    pub fn is_pure(&self) -> bool {
        self.lo == self.hi || self.hi_weight <= 0.0
    }
}

/// A model-level fractional allocation.
#[derive(Clone, Debug)]
pub struct FracAllocation {
    /// Per tensor, same order as the input curves.
    pub choices: Vec<MixChoice>,
    /// Element-weighted average of the realised per-tensor rates.
    pub average: f64,
    /// `average − target`: 0.0 inside the reachable range, nonzero when
    /// the budget fell below/above the hull span and was clamped — the
    /// caller-visible record that the target was not representable.
    pub residual: f64,
}

/// Solve the Fisher-weighted budget problem over measured hulls.
///
/// Start every tensor at its cheapest hull vertex, then spend the
/// remaining budget on hull segments in decreasing weighted-gain ratio
/// `f̄_t·(e_lo − e_hi) / (N_t·(b_hi − b_lo))`, splitting only the
/// marginal segment — so at most one tensor ends up genuinely mixed and
/// every other sits on a hull vertex (the LP-vertex structure of the
/// relaxation).  Budgets outside the reachable range clamp to the nearest
/// end with the shortfall recorded in [`FracAllocation::residual`].
pub fn waterfill(curves: &[TensorCurve], target_bits: f64) -> FracAllocation {
    assert!(!curves.is_empty());
    let total: f64 = curves.iter().map(|c| c.numel as f64).sum();
    let budget = target_bits * total;

    let mut choices: Vec<MixChoice> = curves
        .iter()
        .map(|c| {
            let p = &c.points[c.hull[0]];
            MixChoice {
                lo: c.hull[0],
                hi: c.hull[0],
                hi_weight: 0.0,
                bits: p.bits,
                sq_err: p.sq_err,
            }
        })
        .collect();
    let mut used: f64 = curves
        .iter()
        .zip(&choices)
        .map(|(c, ch)| ch.bits * c.numel as f64)
        .sum();

    struct Seg {
        ratio: f64,
        t: usize,
        v: usize,
    }
    let mut segs: Vec<Seg> = Vec::new();
    for (t, c) in curves.iter().enumerate() {
        for v in 0..c.hull.len().saturating_sub(1) {
            let a = &c.points[c.hull[v]];
            let b = &c.points[c.hull[v + 1]];
            let cost = (b.bits - a.bits) * c.numel as f64;
            let gain = c.fisher.max(1e-30) * (a.sq_err - b.sq_err);
            segs.push(Seg {
                ratio: gain / cost,
                t,
                v,
            });
        }
    }
    // descending ratio; hull convexity keeps each tensor's own segments
    // already descending, and the (t, v) tie-break pins exact ties so the
    // allocation — and therefore the pack — is deterministic
    segs.sort_by(|x, y| {
        y.ratio
            .partial_cmp(&x.ratio)
            .unwrap()
            .then(x.t.cmp(&y.t))
            .then(x.v.cmp(&y.v))
    });

    for s in &segs {
        let c = &curves[s.t];
        let a = &c.points[c.hull[s.v]];
        let b = &c.points[c.hull[s.v + 1]];
        let cost = (b.bits - a.bits) * c.numel as f64;
        if used + cost <= budget + 1e-9 {
            choices[s.t] = MixChoice {
                lo: c.hull[s.v + 1],
                hi: c.hull[s.v + 1],
                hi_weight: 0.0,
                bits: b.bits,
                sq_err: b.sq_err,
            };
            used += cost;
        } else {
            let w = ((budget - used) / cost).clamp(0.0, 1.0);
            if w > 0.0 {
                choices[s.t] = MixChoice {
                    lo: c.hull[s.v],
                    hi: c.hull[s.v + 1],
                    hi_weight: w,
                    bits: a.bits + w * (b.bits - a.bits),
                    sq_err: a.sq_err + w * (b.sq_err - a.sq_err),
                };
            }
            break; // budget exhausted; every later segment has lower ratio
        }
    }

    let average = curves
        .iter()
        .zip(&choices)
        .map(|(c, ch)| ch.bits * c.numel as f64)
        .sum::<f64>()
        / total;
    FracAllocation {
        choices,
        average,
        residual: average - target_bits,
    }
}

/// The model-level weighted error an allocation predicts (for comparing
/// allocations on the same curves — the hull-optimality assertions).
pub fn weighted_err(curves: &[TensorCurve], alloc: &FracAllocation) -> f64 {
    curves
        .iter()
        .zip(&alloc.choices)
        .map(|(c, ch)| c.fisher.max(1e-30) * ch.sq_err)
        .sum()
}

/// Deterministic block→scheme assignment for a two-scheme mix: 1 marks a
/// `hi` block.  Blocks are visited in an order keyed by
/// `fnv1a64(seed ‖ block_index)` — a fixed function of the tensor-name
/// seed, so re-packing reproduces the assignment byte-for-byte — and a
/// block joins the `hi` partition while doing so keeps the realised
/// element count closest to `hi_elems` (take iff at least half the block
/// still fits).  The realised count therefore lands within half a block
/// of the target.
pub fn assign_blocks(
    seed: u64,
    block_lens: &[usize],
    hi_elems: usize,
) -> Vec<u8> {
    let key = |i: usize| -> u64 {
        let mut bytes = [0u8; 16];
        bytes[..8].copy_from_slice(&seed.to_le_bytes());
        bytes[8..].copy_from_slice(&(i as u64).to_le_bytes());
        crate::artifact::fnv1a64(&bytes)
    };
    let mut order: Vec<usize> = (0..block_lens.len()).collect();
    order.sort_by_key(|&i| (key(i), i));
    let mut assign = vec![0u8; block_lens.len()];
    let mut assigned = 0usize;
    for &i in &order {
        let remaining = hi_elems.saturating_sub(assigned);
        if remaining == 0 {
            break;
        }
        if 2 * remaining >= block_lens[i] {
            assign[i] = 1;
            assigned += block_lens[i];
        }
    }
    assign
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn pt(bits: f64, sq_err: f64) -> SchemePoint {
        SchemePoint {
            spec: format!("int@{bits}"),
            bits,
            sq_err,
        }
    }

    fn base() -> Scheme {
        Scheme::parse("int@4:block64-absmax").unwrap()
    }

    #[test]
    fn hull_of_single_point_is_that_point() {
        assert_eq!(lower_hull(&[pt(4.0, 1.0)]), vec![0]);
    }

    #[test]
    fn hull_drops_collinear_middles_dominated_points_and_flat_tails() {
        // (2,8)–(3,6)–(4,4) are collinear: the middle is realisable as a
        // mix of the endpoints and must not be a hull vertex
        let pts = vec![
            pt(2.0, 8.0),
            pt(3.0, 6.0),
            pt(4.0, 4.0),
            pt(3.5, 7.0), // above the chord: dominated
            pt(5.0, 4.0), // more bits, no less error: pruned tail
            pt(3.0, 9.0), // equal-bits duplicate, worse: dropped
        ];
        let hull = lower_hull(&pts);
        assert_eq!(hull, vec![0, 2], "{hull:?}");
        let b: Vec<f64> = hull.iter().map(|&i| pts[i].bits).collect();
        assert!(b.windows(2).all(|w| w[0] < w[1]));
        let e: Vec<f64> = hull.iter().map(|&i| pts[i].sq_err).collect();
        assert!(e.windows(2).all(|w| w[0] > w[1]));
    }

    #[test]
    fn waterfill_hits_budget_exactly_inside_hull() {
        let curves = vec![
            TensorCurve::new(
                "a",
                1000,
                2.0,
                vec![pt(2.0, 16.0), pt(4.0, 4.0), pt(8.0, 0.5)],
            ),
            TensorCurve::new(
                "b",
                3000,
                1.0,
                vec![pt(2.0, 9.0), pt(3.0, 5.0), pt(6.0, 1.0)],
            ),
        ];
        for target in [2.5, 3.3, 4.7, 6.1] {
            let a = waterfill(&curves, target);
            assert!(
                (a.average - target).abs() < 1e-9,
                "target {target}: avg {}",
                a.average
            );
            assert!(a.residual.abs() < 1e-9);
            // LP-vertex structure: at most one tensor is genuinely mixed
            assert!(
                a.choices.iter().filter(|c| !c.is_pure()).count() <= 1,
                "target {target}: {:?}",
                a.choices
            );
        }
    }

    #[test]
    fn waterfill_clamps_out_of_range_budgets_with_recorded_residual() {
        let curves = vec![TensorCurve::new(
            "a",
            1000,
            1.0,
            vec![pt(2.5, 4.0), pt(6.5, 1.0)],
        )];
        let lo = waterfill(&curves, 1.0);
        assert_eq!(lo.average, 2.5);
        assert!((lo.residual - 1.5).abs() < 1e-12, "{}", lo.residual);
        assert!(lo.choices[0].is_pure());
        let hi = waterfill(&curves, 20.0);
        assert_eq!(hi.average, 6.5);
        assert!((hi.residual + 13.5).abs() < 1e-12, "{}", hi.residual);
        assert!(hi.choices[0].is_pure());
    }

    #[test]
    fn waterfill_on_a_singleton_hull_is_pure_with_residual() {
        // one candidate (or one dominating point): nothing to mix
        let curves =
            vec![TensorCurve::new("z", 256, 1.0, vec![pt(4.25, 0.0)])];
        let a = waterfill(&curves, 3.3);
        assert!(a.choices[0].is_pure());
        assert_eq!(a.average, 4.25);
        assert!((a.residual - 0.95).abs() < 1e-12);
    }

    #[test]
    fn candidate_set_spans_the_documented_lattice() {
        let cands = candidate_schemes(&base());
        assert_eq!(cands.len(), 7);
        assert_eq!(cands[0].bits, 2.0);
        assert_eq!(cands.last().unwrap().bits, 8.0);
        for c in &cands {
            assert_eq!(c.granularity, base().granularity);
        }
    }

    #[test]
    fn validate_base_rejects_unmixable_schemes() {
        assert!(validate_base(&base()).is_ok());
        let tensor =
            Scheme::parse("int@4:tensor-rms").unwrap();
        assert!(validate_base(&tensor).is_err());
        let sparse =
            Scheme::parse("int@4:block64-absmax:sparse0.01").unwrap();
        assert!(validate_base(&sparse).is_err());
        let grid = Scheme::parse("grid@4:tensor-rms").unwrap();
        assert!(validate_base(&grid).is_err());
    }

    #[test]
    fn measured_points_are_deterministic_and_bits_monotone() {
        let mut rng = Rng::new(7);
        let data: Vec<f32> = rng.student_t_vec(5.0, 4096);
        let p1 =
            measure_points(&base(), &data, &[4096], None, &[], 99).unwrap();
        let p2 =
            measure_points(&base(), &data, &[4096], None, &[], 99).unwrap();
        assert_eq!(p1.len(), 7);
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(a.bits.to_bits(), b.bits.to_bits());
            assert_eq!(a.sq_err.to_bits(), b.sq_err.to_bits());
        }
        // plain (non-compress) int schemes: effective bits are k + scale
        // overhead, strictly increasing in k
        for w in p1.windows(2) {
            assert!(w[0].bits < w[1].bits);
        }
    }

    #[test]
    fn waterfill_beats_best_single_scheme_on_measured_points() {
        // the acceptance-criterion optimality check, on real measured
        // curves: at every budget, the water-filled mix must predict a
        // weighted error no worse than any single candidate scheme whose
        // flat allocation fits the same budget
        let mut rng = Rng::new(21);
        let a: Vec<f32> = rng.student_t_vec(5.0, 4096);
        let b: Vec<f32> =
            rng.student_t_vec(5.0, 4096).iter().map(|x| x * 0.05).collect();
        let curves = vec![
            TensorCurve::new(
                "a",
                4096,
                4.0,
                measure_points(&base(), &a, &[4096], None, &[], 1).unwrap(),
            ),
            TensorCurve::new(
                "b",
                4096,
                1.0,
                measure_points(&base(), &b, &[4096], None, &[], 2).unwrap(),
            ),
        ];
        for target in [2.5, 3.3, 4.7, 6.1] {
            let alloc = waterfill(&curves, target);
            assert!(alloc.residual.abs() < 1e-9, "target {target}");
            let wf = weighted_err(&curves, &alloc);
            for k in 0..curves[0].points.len() {
                let flat_bits: f64 = curves
                    .iter()
                    .map(|c| c.points[k].bits * c.numel as f64)
                    .sum::<f64>()
                    / curves.iter().map(|c| c.numel as f64).sum::<f64>();
                if flat_bits > target + 1e-9 {
                    continue; // this single scheme busts the budget
                }
                let flat: f64 = curves
                    .iter()
                    .map(|c| c.fisher * c.points[k].sq_err)
                    .sum();
                assert!(
                    wf <= flat * (1.0 + 1e-9) + 1e-12,
                    "target {target}: mix {wf} vs flat[{k}] {flat}"
                );
            }
        }
    }

    #[test]
    fn block_assignment_is_deterministic_and_hits_the_target_count() {
        let mut lens = vec![64usize; 63];
        lens.push(32); // tail block
        let total: usize = lens.iter().sum();
        for hi in [0, 500, 1500, total] {
            let a1 = assign_blocks(0xABCD, &lens, hi);
            let a2 = assign_blocks(0xABCD, &lens, hi);
            assert_eq!(a1, a2);
            let got: usize = a1
                .iter()
                .zip(&lens)
                .filter(|(&m, _)| m == 1)
                .map(|(_, &l)| l)
                .sum();
            assert!(
                got.abs_diff(hi) <= 32,
                "hi {hi}: realised {got}"
            );
        }
        // a different seed shuffles the assignment (overwhelmingly)
        let a = assign_blocks(1, &lens, 1500);
        let b = assign_blocks(2, &lens, 1500);
        assert_ne!(a, b);
    }
}
