//! Variable bit-width allocation across parameter tensors (§2.4, eq. 5,
//! appendix B.5).
//!
//! ```text
//! b*_t = b⁰ + log2 RMS(θ_t) + ½ log2 f̄_t
//! ```
//!
//! with b⁰ chosen (here by bisection, with [min,max] clamping) to satisfy
//! the model-level average-bits constraint Σ N_t·b_t ≤ b·Σ N_t.  Also
//! implements the paper's baselines: flat allocation and the *heuristic*
//! scheme of fig. 30 (+2 bits to the first/last two layers and the
//! embedding/head), which the paper shows performs poorly.

/// Per-tensor inputs to the allocator.
#[derive(Clone, Debug)]
pub struct TensorInfo {
    pub name: String,
    pub numel: usize,
    pub rms: f64,
    /// Mean of the Fisher diagonal over the tensor (f̄_t).
    pub fisher_mean: f64,
}

/// An allocation: bits per tensor, same order as the input.
#[derive(Clone, Debug)]
pub struct Allocation {
    pub bits: Vec<f64>,
    pub average: f64,
}

/// Allocation strategy (fig. 6 / fig. 30 comparisons).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllocScheme {
    Flat,
    /// eq. (5) Fisher + RMS optimal.
    Variable,
    /// +2 bits to first/last 2 layers, embedding and head (fig. 30).
    Heuristic,
}

/// Default bounds applied to per-tensor bit widths — the candidate
/// lattice the repo can actually realise ([`frac::CANDIDATE_MIN_BITS`]
/// ..= [`frac::CANDIDATE_MAX_BITS`], i.e. formats exist for 2..=8 bits;
/// fractional values are meaningful for √[3]p/grid formats and realised
/// for integer-LUT formats by block-level scheme mixing in [`frac`]).
/// Callers with a different candidate set derive their own clamp range
/// with [`bits_bounds`] and pass it to [`variable_allocation_bounded`]
/// instead of re-clamping ad hoc.
pub const MIN_BITS: f64 = frac::CANDIDATE_MIN_BITS as f64;
pub const MAX_BITS: f64 = frac::CANDIDATE_MAX_BITS as f64;

pub mod frac;

/// The clamp range implied by a concrete candidate scheme set: the min
/// and max `bits` over the schemes the caller can realise.  This is the
/// one place the allocator learns which bit widths exist — the constants
/// above are just this function evaluated on the default integer lattice.
pub fn bits_bounds(
    candidates: &[crate::coordinator::config::Scheme],
) -> (f64, f64) {
    assert!(!candidates.is_empty(), "no candidate schemes to bound by");
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for s in candidates {
        lo = lo.min(s.bits);
        hi = hi.max(s.bits);
    }
    (lo, hi)
}

/// Compute the eq.-(5) allocation for an average budget of `target_bits`,
/// clamped to the default candidate lattice.
pub fn variable_allocation(
    tensors: &[TensorInfo],
    target_bits: f64,
) -> Allocation {
    variable_allocation_bounded(tensors, target_bits, (MIN_BITS, MAX_BITS))
}

/// Compute the eq.-(5) allocation with an explicit clamp range (from
/// [`bits_bounds`] over the caller's candidate schemes).
pub fn variable_allocation_bounded(
    tensors: &[TensorInfo],
    target_bits: f64,
    (min_bits, max_bits): (f64, f64),
) -> Allocation {
    assert!(!tensors.is_empty());
    // offsets o_t = log2 rms + 0.5 log2 fisher (guard degenerate stats)
    let offsets: Vec<f64> = tensors
        .iter()
        .map(|t| {
            let rms = t.rms.max(1e-30);
            let f = t.fisher_mean.max(1e-30);
            rms.log2() + 0.5 * f.log2()
        })
        .collect();
    let total: f64 = tensors.iter().map(|t| t.numel as f64).sum();
    let avg = |b0: f64| -> f64 {
        tensors
            .iter()
            .zip(&offsets)
            .map(|(t, o)| {
                (b0 + o).clamp(min_bits, max_bits) * t.numel as f64
            })
            .sum::<f64>()
            / total
    };
    // bisection on b0 (avg is monotone in b0)
    let (mut lo, mut hi) = (-80.0f64, 80.0f64);
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if avg(mid) < target_bits {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let b0 = 0.5 * (lo + hi);
    let bits: Vec<f64> = offsets
        .iter()
        .map(|o| (b0 + o).clamp(min_bits, max_bits))
        .collect();
    let average = avg(b0);
    Allocation { bits, average }
}

/// Flat allocation at exactly `target_bits`.
pub fn flat_allocation(tensors: &[TensorInfo], target_bits: f64) -> Allocation {
    Allocation {
        bits: vec![target_bits; tensors.len()],
        average: target_bits,
    }
}

/// The fig.-30 heuristic: +2 bits for embed/head and the first/last two
/// layers, with the base level set to hit the average budget.
pub fn heuristic_allocation(
    tensors: &[TensorInfo],
    target_bits: f64,
    n_layers: usize,
) -> Allocation {
    let boosted: Vec<bool> = tensors
        .iter()
        .map(|t| is_boosted(&t.name, n_layers))
        .collect();
    let total: f64 = tensors.iter().map(|t| t.numel as f64).sum();
    let boosted_n: f64 = tensors
        .iter()
        .zip(&boosted)
        .filter(|(_, &b)| b)
        .map(|(t, _)| t.numel as f64)
        .sum();
    // base + 2·(boosted fraction) = target
    let base = target_bits - 2.0 * boosted_n / total;
    let bits: Vec<f64> = boosted
        .iter()
        .map(|&b| {
            (if b { base + 2.0 } else { base }).clamp(MIN_BITS, MAX_BITS)
        })
        .collect();
    let average = bits
        .iter()
        .zip(tensors)
        .map(|(b, t)| b * t.numel as f64)
        .sum::<f64>()
        / total;
    Allocation { bits, average }
}

fn is_boosted(name: &str, n_layers: usize) -> bool {
    if name == "embed_tokens" || name == "lm_head" {
        return true;
    }
    if let Some(rest) = name.strip_prefix("layers.") {
        if let Some(idx) = rest.split('.').next() {
            if let Ok(i) = idx.parse::<usize>() {
                return i < 2 || i + 2 >= n_layers;
            }
        }
    }
    false
}

/// Round an allocation to integer bits while preserving the budget:
/// floor everything, then promote the tensors with the largest fractional
/// part until the average budget is used up (largest-remainder method).
pub fn round_allocation(
    tensors: &[TensorInfo],
    alloc: &Allocation,
    target_bits: f64,
) -> Allocation {
    let total: f64 = tensors.iter().map(|t| t.numel as f64).sum();
    let mut bits: Vec<f64> =
        alloc.bits.iter().map(|b| b.floor()).collect();
    let mut used: f64 = bits
        .iter()
        .zip(tensors)
        .map(|(b, t)| b * t.numel as f64)
        .sum();
    let budget = target_bits * total;
    let mut order: Vec<usize> = (0..bits.len()).collect();
    order.sort_by(|&a, &b| {
        let fa = alloc.bits[a] - alloc.bits[a].floor();
        let fb = alloc.bits[b] - alloc.bits[b].floor();
        fb.partial_cmp(&fa).unwrap()
    });
    for &i in &order {
        let cost = tensors[i].numel as f64;
        if used + cost <= budget + 1e-9 {
            bits[i] += 1.0;
            used += cost;
        }
    }
    Allocation {
        average: used / total,
        bits,
    }
}

/// Predicted KL from eq. (3) + Zador: ½ Σ N_t f̄_t ε² RMS² 2^(−2b_t)
/// (constant ε dropped — useful for *comparing* allocations).
pub fn predicted_kl(tensors: &[TensorInfo], alloc: &Allocation) -> f64 {
    tensors
        .iter()
        .zip(&alloc.bits)
        .map(|(t, &b)| {
            0.5 * t.numel as f64
                * t.fisher_mean
                * t.rms
                * t.rms
                * 2f64.powf(-2.0 * b)
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(name: &str, numel: usize, rms: f64, fisher: f64) -> TensorInfo {
        TensorInfo {
            name: name.into(),
            numel,
            rms,
            fisher_mean: fisher,
        }
    }

    fn example() -> Vec<TensorInfo> {
        vec![
            mk("embed_tokens", 1000, 0.02, 1e-6),
            mk("layers.0.self_attn.v_proj", 500, 0.05, 1e-3),
            mk("layers.1.mlp.down_proj", 2000, 0.03, 1e-5),
            mk("lm_head", 1000, 0.04, 1e-4),
        ]
    }

    #[test]
    fn budget_respected() {
        let tensors = example();
        for target in [3.0, 4.0, 6.0] {
            let a = variable_allocation(&tensors, target);
            assert!(
                (a.average - target).abs() < 1e-6,
                "target {target}: avg {}",
                a.average
            );
        }
    }

    #[test]
    fn four_x_fisher_is_one_more_bit() {
        // the paper's intuition: 4× Fisher ⇒ +1 bit
        let tensors = vec![
            mk("a", 1000, 0.1, 4e-4),
            mk("b", 1000, 0.1, 1e-4),
        ];
        // target 5.0 keeps both tensors inside the [2, 8] clamp range
        let a = variable_allocation(&tensors, 5.0);
        assert!(
            (a.bits[0] - a.bits[1] - 1.0).abs() < 1e-9,
            "{:?}",
            a.bits
        );
    }

    #[test]
    fn monotone_in_fisher_and_rms() {
        let tensors = example();
        let a = variable_allocation(&tensors, 4.0);
        // v_proj has the highest fisher — should get the most bits
        let vmax = a.bits[1];
        for (i, b) in a.bits.iter().enumerate() {
            if i != 1 {
                assert!(vmax >= *b, "{:?}", a.bits);
            }
        }
    }

    #[test]
    fn clamping_still_meets_budget_when_feasible() {
        let tensors = vec![
            mk("a", 100, 1e-9, 1e-12), // will clamp to MIN_BITS
            mk("b", 100, 1.0, 1.0),
        ];
        // 4.0 is feasible with a pinned at 2: b gets 6 (inside [2, 8])
        let a = variable_allocation(&tensors, 4.0);
        assert!((a.average - 4.0).abs() < 1e-6, "avg {}", a.average);
        assert_eq!(a.bits[0], MIN_BITS);
    }

    #[test]
    fn clamp_bounds_follow_the_candidate_lattice() {
        use crate::coordinator::config::Scheme;
        // the doc'd 2..=8 range IS the integer candidate lattice — the
        // constants must stay derived, not drift independently
        assert_eq!(MIN_BITS, frac::CANDIDATE_MIN_BITS as f64);
        assert_eq!(MAX_BITS, frac::CANDIDATE_MAX_BITS as f64);
        assert_eq!((MIN_BITS, MAX_BITS), (2.0, 8.0));
        let base = Scheme::parse("int@4:block64-absmax").unwrap();
        let cands = frac::candidate_schemes(&base);
        assert_eq!(bits_bounds(&cands), (MIN_BITS, MAX_BITS));
        // and an allocation clamped by the derived range never leaves it,
        // even when the stats would push a tensor far outside
        let tensors = vec![
            mk("tiny", 100, 1e-9, 1e-12),
            mk("huge", 100, 10.0, 1.0),
        ];
        let a =
            variable_allocation_bounded(&tensors, 5.0, bits_bounds(&cands));
        assert!(
            a.bits.iter().all(|&b| (MIN_BITS..=MAX_BITS).contains(&b)),
            "{:?}",
            a.bits
        );
    }

    #[test]
    fn heuristic_boosts_right_tensors() {
        let tensors = vec![
            mk("embed_tokens", 100, 0.1, 1e-4),
            mk("layers.0.mlp.up_proj", 100, 0.1, 1e-4),
            mk("layers.3.mlp.up_proj", 100, 0.1, 1e-4),
            mk("layers.5.mlp.up_proj", 100, 0.1, 1e-4),
            mk("lm_head", 100, 0.1, 1e-4),
        ];
        let a = heuristic_allocation(&tensors, 4.0, 8);
        assert!((a.average - 4.0).abs() < 1e-9);
        // layer 3 and 5 of 8 are not boosted
        assert!(a.bits[0] > a.bits[2]);
        assert!((a.bits[0] - a.bits[2] - 2.0).abs() < 1e-9);
        assert_eq!(a.bits[2], a.bits[3]);
        assert!(a.bits[4] > a.bits[2]);
    }

    #[test]
    fn rounding_preserves_budget_and_integrality() {
        let tensors = example();
        let a = variable_allocation(&tensors, 4.0);
        let r = round_allocation(&tensors, &a, 4.0);
        assert!(r.average <= 4.0 + 1e-9);
        assert!(r.average > 3.0);
        for b in &r.bits {
            assert_eq!(b.fract(), 0.0);
        }
    }

    #[test]
    fn variable_beats_flat_on_predicted_kl() {
        // the whole point of eq. (5)
        let tensors = example();
        let flat = flat_allocation(&tensors, 4.0);
        let var = variable_allocation(&tensors, 4.0);
        let kl_flat = predicted_kl(&tensors, &flat);
        let kl_var = predicted_kl(&tensors, &var);
        assert!(
            kl_var < kl_flat,
            "variable {kl_var} should beat flat {kl_flat}"
        );
    }
}
