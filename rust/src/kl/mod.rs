//! Top-k KL divergence and related evaluation metrics (§D).
//!
//! The paper's comparison metric: per token, take the reference model's
//! top-k classes, compare the two predictive distributions over those k
//! classes plus a collapsed tail class.  Always ≥ 0; the top-k is taken from
//! the *reference* model only.

/// Top-k KL divergence between two logit vectors (one token position).
/// `k` is clamped to the vocabulary size.
pub fn topk_kl_token(ref_logits: &[f32], test_logits: &[f32], k: usize) -> f64 {
    assert_eq!(ref_logits.len(), test_logits.len());
    let v = ref_logits.len();
    let k = k.min(v);
    // softmax both in f64 with max-subtraction
    let p = softmax64(ref_logits);
    let q = softmax64(test_logits);
    // top-k indices of the reference distribution
    let mut idx: Vec<u32> = (0..v as u32).collect();
    idx.select_nth_unstable_by(k.saturating_sub(1), |&a, &b| {
        p[b as usize].partial_cmp(&p[a as usize]).unwrap()
    });
    let mut kl = 0.0f64;
    let mut p_tail = 1.0f64;
    let mut q_tail = 1.0f64;
    for &i in &idx[..k] {
        let (pi, qi) = (p[i as usize], q[i as usize]);
        p_tail -= pi;
        q_tail -= qi;
        if pi > 0.0 {
            kl += pi * (pi / qi.max(1e-300)).ln();
        }
    }
    // collapsed tail term keeps the divergence ≥ 0
    let p_tail = p_tail.max(0.0);
    let q_tail = q_tail.max(1e-300);
    if p_tail > 0.0 {
        kl += p_tail * (p_tail / q_tail).ln();
    }
    kl.max(0.0)
}

fn softmax64(logits: &[f32]) -> Vec<f64> {
    let max = logits.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x)) as f64;
    let exps: Vec<f64> =
        logits.iter().map(|&x| ((x as f64) - max).exp()).collect();
    let sum: f64 = exps.iter().sum();
    exps.into_iter().map(|e| e / sum).collect()
}

/// Mean top-k KL over a batch of token positions.
/// `ref_logits`/`test_logits` are row-major (tokens × vocab).
pub fn topk_kl_batch(
    ref_logits: &[f32],
    test_logits: &[f32],
    vocab: usize,
    k: usize,
) -> KlSummary {
    assert_eq!(ref_logits.len(), test_logits.len());
    assert_eq!(ref_logits.len() % vocab, 0);
    let n = ref_logits.len() / vocab;
    let per_token = crate::util::pool::par_map(
        &(0..n).collect::<Vec<_>>(),
        |_, &t| {
            topk_kl_token(
                &ref_logits[t * vocab..(t + 1) * vocab],
                &test_logits[t * vocab..(t + 1) * vocab],
                k,
            )
        },
    );
    KlSummary::from_samples(&per_token)
}

/// Cross-entropy (nats/token) of targets under logits (tokens × vocab).
pub fn cross_entropy_batch(
    logits: &[f32],
    targets: &[i32],
    vocab: usize,
) -> f64 {
    assert_eq!(logits.len() % vocab, 0);
    assert_eq!(logits.len() / vocab, targets.len());
    let mut total = 0.0;
    for (t, &y) in targets.iter().enumerate() {
        let row = &logits[t * vocab..(t + 1) * vocab];
        let p = softmax64(row);
        total += -(p[y as usize].max(1e-300)).ln();
    }
    total / targets.len() as f64
}

/// Argmax accuracy of logits against targets — the downstream-task proxy
/// metric (tables 1-2 analogue).
pub fn argmax_accuracy(logits: &[f32], targets: &[i32], vocab: usize) -> f64 {
    let n = targets.len();
    let mut hits = 0usize;
    for (t, &y) in targets.iter().enumerate() {
        let row = &logits[t * vocab..(t + 1) * vocab];
        let mut best = 0usize;
        for (i, &x) in row.iter().enumerate() {
            if x > row[best] {
                best = i;
            }
        }
        if best == y as usize {
            hits += 1;
        }
    }
    hits as f64 / n as f64
}

/// Mean ± standard-error summary of per-token KL samples (the ±2 SE bands
/// in figs. 1/7/8).
#[derive(Clone, Copy, Debug)]
pub struct KlSummary {
    pub mean: f64,
    pub sem: f64,
    pub n: usize,
}

impl KlSummary {
    pub fn from_samples(samples: &[f64]) -> KlSummary {
        KlSummary {
            mean: crate::util::stats::mean(samples),
            sem: crate::util::stats::sem(samples),
            n: samples.len(),
        }
    }

    /// ρ := KL · 2^(2b), the scaled-KL inefficiency measure (fig. 8).
    pub fn rho(&self, bits: f64) -> f64 {
        self.mean * 2f64.powf(2.0 * bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_logits(rng: &mut Rng, v: usize) -> Vec<f32> {
        (0..v).map(|_| rng.normal() as f32 * 2.0).collect()
    }

    #[test]
    fn identical_distributions_zero() {
        let mut rng = Rng::new(1);
        let l = rand_logits(&mut rng, 100);
        assert!(topk_kl_token(&l, &l, 16) < 1e-12);
    }

    #[test]
    fn always_nonnegative() {
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let a = rand_logits(&mut rng, 64);
            let b = rand_logits(&mut rng, 64);
            assert!(topk_kl_token(&a, &b, 8) >= 0.0);
        }
    }

    #[test]
    fn full_k_equals_exact_kl() {
        let mut rng = Rng::new(3);
        let a = rand_logits(&mut rng, 32);
        let b = rand_logits(&mut rng, 32);
        let topk = topk_kl_token(&a, &b, 32);
        // exact KL
        let p = softmax64(&a);
        let q = softmax64(&b);
        let exact: f64 = p
            .iter()
            .zip(&q)
            .map(|(&pi, &qi)| if pi > 0.0 { pi * (pi / qi).ln() } else { 0.0 })
            .sum();
        assert!((topk - exact).abs() < 1e-9);
    }

    #[test]
    fn topk_le_exact_kl() {
        // collapsing the tail can only lose information (data processing)
        let mut rng = Rng::new(4);
        for _ in 0..50 {
            let a = rand_logits(&mut rng, 64);
            let b = rand_logits(&mut rng, 64);
            let topk = topk_kl_token(&a, &b, 8);
            let exact = topk_kl_token(&a, &b, 64);
            assert!(topk <= exact + 1e-9, "{topk} > {exact}");
        }
    }

    #[test]
    fn grows_with_perturbation() {
        let mut rng = Rng::new(5);
        let a = rand_logits(&mut rng, 128);
        let mut prev = 0.0;
        for scale in [0.01f32, 0.1, 0.5, 2.0] {
            let b: Vec<f32> = a
                .iter()
                .map(|&x| x + scale * rng.normal() as f32)
                .collect();
            let kl = topk_kl_token(&a, &b, 32);
            assert!(kl >= prev * 0.2, "kl should roughly grow: {kl} vs {prev}");
            prev = kl;
        }
    }

    #[test]
    fn batch_summary() {
        let mut rng = Rng::new(6);
        let vocab = 32;
        let n = 50;
        let mut a = Vec::new();
        let mut b = Vec::new();
        for _ in 0..n {
            let r = rand_logits(&mut rng, vocab);
            let t: Vec<f32> =
                r.iter().map(|&x| x + 0.1 * rng.normal() as f32).collect();
            a.extend_from_slice(&r);
            b.extend(t);
        }
        let s = topk_kl_batch(&a, &b, vocab, 8);
        assert_eq!(s.n, n);
        assert!(s.mean > 0.0);
        assert!(s.sem > 0.0 && s.sem < s.mean);
        // rho at 4 bits = mean * 256
        assert!((s.rho(4.0) - s.mean * 256.0).abs() < 1e-12);
    }

    #[test]
    fn ce_and_accuracy() {
        // logits strongly favouring the target ⇒ low CE, high accuracy
        let vocab = 8;
        let targets = [1i32, 3, 5];
        let mut logits = vec![0f32; targets.len() * vocab];
        for (t, &y) in targets.iter().enumerate() {
            logits[t * vocab + y as usize] = 10.0;
        }
        assert!(cross_entropy_batch(&logits, &targets, vocab) < 0.01);
        assert_eq!(argmax_accuracy(&logits, &targets, vocab), 1.0);
    }
}
