"""L2 correctness: microllama forward/losses/Fisher/QAT graphs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data
from compile.model import (
    CONFIGS,
    Config,
    adam_step,
    ce_loss,
    empirical_fisher_batch,
    fisher_batch,
    init_params,
    kl_to_ref,
    logits_fn,
    qat_logits,
)

TINY = Config("tiny", vocab=64, d_model=32, n_layers=2, n_heads=4,
              n_kv_heads=2, d_ff=64, seq_len=32)


@pytest.fixture(scope="module")
def tiny_setup():
    params = init_params(TINY, jax.random.PRNGKey(0))
    corpus = data.Corpus(TINY.vocab, domain=0)
    tokens = jnp.asarray(
        corpus.sample(np.random.default_rng(0), 4, TINY.seq_len)
    )
    return params, tokens


def test_param_shapes_and_count(tiny_setup):
    params, _ = tiny_setup
    shapes = TINY.param_shapes()
    assert set(params) == set(shapes)
    for k, s in shapes.items():
        assert params[k].shape == s
    assert TINY.n_params() == sum(int(np.prod(s)) for s in shapes.values())


def test_forward_shape_and_finite(tiny_setup):
    params, tokens = tiny_setup
    logits = logits_fn(TINY, params, tokens)
    assert logits.shape == (4, TINY.seq_len, TINY.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_causality(tiny_setup):
    """Changing a future token must not change past logits."""
    params, tokens = tiny_setup
    logits = logits_fn(TINY, params, tokens)
    mutated = tokens.at[:, -1].set((tokens[:, -1] + 1) % TINY.vocab)
    logits2 = logits_fn(TINY, params, mutated)
    np.testing.assert_allclose(
        np.asarray(logits[:, :-1]), np.asarray(logits2[:, :-1]),
        rtol=1e-5, atol=1e-6,
    )


def test_training_reduces_loss(tiny_setup):
    params, tokens = tiny_setup
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(p) for k, p in params.items()}
    step_fn = jax.jit(
        lambda p, m, v, s, t: adam_step(
            lambda q: ce_loss(TINY, q, t), p, m, v, s, 1e-2
        )
    )
    first = None
    corpus = data.Corpus(TINY.vocab, domain=0)
    rng = np.random.default_rng(1)
    for step in range(30):
        toks = jnp.asarray(corpus.sample(rng, 8, TINY.seq_len))
        params, m, v, loss = step_fn(params, m, v, jnp.float32(step), toks)
        first = first if first is not None else float(loss)
    assert float(loss) < first - 0.1, (first, float(loss))


def test_fisher_positive_and_shaped(tiny_setup):
    params, tokens = tiny_setup
    f = fisher_batch(TINY, params, tokens, jax.random.PRNGKey(1))
    assert set(f) == set(params)
    for k in params:
        assert f[k].shape == params[k].shape
        assert bool(jnp.all(f[k] >= 0))
    # at least the value projections should carry signal
    assert float(jnp.sum(f["layers.0.self_attn.v_proj"])) > 0


def test_empirical_fisher_close_in_structure(tiny_setup):
    """Sampled vs empirical Fisher should correlate across tensors (fig 27)."""
    params, tokens = tiny_setup
    fs = fisher_batch(TINY, params, tokens, jax.random.PRNGKey(2))
    fe = empirical_fisher_batch(TINY, params, tokens)
    a = np.array([float(jnp.mean(fs[k])) for k in sorted(fs)])
    b = np.array([float(jnp.mean(fe[k])) for k in sorted(fe)])
    corr = np.corrcoef(np.log(a + 1e-20), np.log(b + 1e-20))[0, 1]
    assert corr > 0.8, corr


def test_qat_logits_differs_but_close(tiny_setup):
    params, tokens = tiny_setup
    cb = jnp.asarray(np.linspace(-1, 1, 16, dtype=np.float32))
    ql = qat_logits(TINY, params, tokens, cb, block=32)
    fl = logits_fn(TINY, params, tokens)
    # quantisation changes the output...
    assert float(jnp.max(jnp.abs(ql - fl))) > 0
    # ...but a 4-bit block format should stay in the same ballpark
    assert float(jnp.mean(jnp.abs(ql - fl))) < float(jnp.mean(jnp.abs(fl)))


def test_qat_kl_loss_nonnegative_and_grads_flow(tiny_setup):
    params, tokens = tiny_setup
    cb = jnp.asarray(np.linspace(-1, 1, 16, dtype=np.float32))
    ref = logits_fn(TINY, params, tokens)
    loss, grads = jax.value_and_grad(
        lambda p: kl_to_ref(TINY, p, tokens, ref, cb, 32, "absmax")
    )(params)
    assert float(loss) >= 0
    # STE must pass gradients through to quantised weights
    g = float(jnp.sum(jnp.abs(grads["layers.0.mlp.down_proj"])))
    assert g > 0


def test_gqa_repeat_consistency():
    """n_kv_heads == n_heads (MHA) must equal GQA with repeated kv weights."""
    mha = Config("mha", vocab=32, d_model=32, n_layers=1, n_heads=4,
                 n_kv_heads=4, d_ff=32, seq_len=16)
    params = init_params(mha, jax.random.PRNGKey(3))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, 32, size=(2, 16), dtype=np.int32))
    logits = logits_fn(mha, params, toks)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_corpus_determinism_and_structure():
    c1 = data.make_split(128, 0, 42, 8, 64)
    c2 = data.make_split(128, 0, 42, 8, 64)
    np.testing.assert_array_equal(c1, c2)
    other = data.make_split(128, 1, 42, 8, 64)
    assert not np.array_equal(c1, other)
    # tokens in range
    assert c1.min() >= 0 and c1.max() < 128
    # Zipfian-leaning marginal: top-quarter ids carry well above the
    # uniform 0.25 mass (structured successors dilute the pure-Zipf skew)
    assert (c1 < 32).mean() > 0.33
