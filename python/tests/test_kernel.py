"""L1 correctness: Pallas qdq kernel vs the pure-jnp oracle.

The hypothesis sweep covers shapes, block sizes, scale modes and codebook
constructions; this is the core correctness signal for the kernel that every
exported QAT graph embeds.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.qdq import ROWS_PER_STEP, qdq_block, qdq_tensor
from compile.kernels.ref import (
    block_scale,
    qdq_block_ref,
    quantise_indices,
    round_scale_bf16_away,
)


def random_codebook(rng: np.random.Generator, k: int) -> np.ndarray:
    """Sorted, deduplicated codebook spanning about [-1, 1]."""
    cb = np.sort(rng.uniform(-1.0, 1.0, size=k).astype(np.float32))
    # ensure strict monotonicity to keep midpoints well-defined
    cb += np.arange(k, dtype=np.float32) * 1e-6
    return cb


@settings(max_examples=40, deadline=None)
@given(
    rows=st.integers(1, 6),
    block=st.sampled_from([32, 64, 128, 256]),
    k=st.sampled_from([4, 8, 15, 16, 32]),
    mode=st.sampled_from(["absmax", "rms"]),
    scale_bf16=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
    dist=st.sampled_from(["normal", "laplace", "student_t", "uniform"]),
)
def test_pallas_matches_ref(rows, block, k, mode, scale_bf16, seed, dist):
    rng = np.random.default_rng(seed)
    n_blocks = rows * ROWS_PER_STEP
    if dist == "normal":
        x = rng.standard_normal((n_blocks, block))
    elif dist == "laplace":
        x = rng.laplace(size=(n_blocks, block))
    elif dist == "student_t":
        x = rng.standard_t(5, size=(n_blocks, block))
    else:
        x = rng.uniform(-2, 2, size=(n_blocks, block))
    x = x.astype(np.float32)
    cb = random_codebook(rng, k)

    got = qdq_block(jnp.asarray(x), jnp.asarray(cb), mode, scale_bf16)
    want = qdq_block_ref(x, jnp.asarray(cb), mode, scale_bf16)
    # Pallas interpret mode may reassociate the scale reduction, so values
    # can differ by ~1 ulp (and a midpoint tie could flip, probability
    # ~1e-7/elem — never observed at these sample counts).
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=3e-6, atol=1e-6
    )


def test_zero_block_is_stable():
    """All-zero blocks must not divide by zero and must map to codebook 0."""
    x = np.zeros((ROWS_PER_STEP, 64), np.float32)
    cb = np.array([-1.0, -0.5, 0.0, 0.5, 1.0], np.float32)
    out = np.asarray(qdq_block(jnp.asarray(x), jnp.asarray(cb)))
    np.testing.assert_array_equal(out, np.zeros_like(x))


def test_round_away_bf16_properties():
    rng = np.random.default_rng(7)
    s = np.abs(rng.standard_normal(1000).astype(np.float32)) + 1e-6
    r = np.asarray(round_scale_bf16_away(jnp.asarray(s)))
    # round-away never shrinks a positive scale
    assert np.all(r >= s)
    # and the result is exactly representable in bfloat16
    bits = r.view(np.uint32)
    assert np.all(bits & 0xFFFF == 0)
    # exact bf16 values are unchanged
    exact = (s.view(np.uint32) & 0xFFFF0000).view(np.float32)
    r2 = np.asarray(round_scale_bf16_away(jnp.asarray(exact)))
    np.testing.assert_array_equal(r2, exact)


def test_absmax_never_clips():
    """With round-away scales, |scaled data| <= 1 so the max is representable."""
    rng = np.random.default_rng(3)
    x = rng.standard_t(4, size=(ROWS_PER_STEP * 2, 128)).astype(np.float32)
    s = np.asarray(round_scale_bf16_away(block_scale(jnp.asarray(x), "absmax")))
    assert np.all(np.abs(x / s[:, None]) <= 1.0 + 1e-7)


def test_quantise_indices_nearest():
    cb = jnp.asarray(np.array([-1.0, 0.0, 2.0], np.float32))
    y = jnp.asarray(np.array([-5.0, -0.51, -0.49, 0.99, 1.01, 9.0], np.float32))
    idx = np.asarray(quantise_indices(y, cb))
    np.testing.assert_array_equal(idx, [0, 0, 1, 1, 2, 2])


def test_qdq_tensor_padding_roundtrip():
    """qdq_tensor pads the tail block; shape and non-padded values survive."""
    rng = np.random.default_rng(11)
    w = rng.standard_normal((37, 53)).astype(np.float32)  # 1961 elements
    cb = random_codebook(rng, 16)
    out = np.asarray(qdq_tensor(jnp.asarray(w), jnp.asarray(cb), block=128))
    assert out.shape == w.shape
    # error bounded by half the largest codebook gap times the block scale
    blocks = np.pad(w.reshape(-1), (0, 128 * 16 - w.size)).reshape(-1, 128)
    scales = np.max(np.abs(blocks), axis=1)
    max_gap = np.max(np.diff(cb))
    # every element's error <= scale * max(gap/2, distance outside range)
    err = np.abs(out - w).reshape(-1)
    per_elem_scale = np.repeat(scales, 128)[: w.size] * 1.01
    assert np.all(err <= per_elem_scale * max(max_gap, 0.5))


def test_qdq_idempotent():
    """Quantising an already-quantised tensor is exact identity for absmax
    formats whose codebook contains the endpoints +-1 (every absmax format
    in the paper does): the block max re-scales to exactly +-1, so the
    second pass sees scale == first-pass scale and every value is already a
    codepoint."""
    rng = np.random.default_rng(5)
    x = rng.standard_normal((ROWS_PER_STEP, 128)).astype(np.float32)
    inner = np.sort(rng.uniform(-0.95, 0.95, 14)).astype(np.float32)
    cb = np.concatenate([[-1.0], inner, [1.0]]).astype(np.float32)
    once = qdq_block(jnp.asarray(x), jnp.asarray(cb), "absmax", True)
    twice = qdq_block(once, jnp.asarray(cb), "absmax", True)
    np.testing.assert_array_equal(np.asarray(twice), np.asarray(once))


def test_duplicate_codepoints_are_harmless():
    """Padding a codebook by duplicating entries must not change results
    (the QAT graphs rely on this to express 3-bit formats in a 16-slot LUT).
    """
    rng = np.random.default_rng(13)
    x = rng.standard_normal((ROWS_PER_STEP, 64)).astype(np.float32)
    cb8 = random_codebook(rng, 8)
    cb16 = np.sort(np.concatenate([cb8, cb8]))
    a = np.asarray(qdq_block(jnp.asarray(x), jnp.asarray(cb8)))
    b = np.asarray(qdq_block(jnp.asarray(x), jnp.asarray(cb16)))
    np.testing.assert_array_equal(a, b)
