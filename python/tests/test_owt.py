"""Round-trip tests for the .owt tensor container (python side; the Rust
reader is tested against files produced here via artifacts)."""

import numpy as np
import pytest

from compile.owt import MAGIC, read_owt, write_owt


def test_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    tensors = {
        "a.weight": rng.standard_normal((17, 9)).astype(np.float32),
        "b.tokens": rng.integers(0, 100, size=(3, 5)).astype(np.int32),
        "c.scalarish": np.array([1.5], np.float32),
    }
    meta = {"kind": "test", "nested": {"x": 1, "y": [1, 2.5, "s"]}}
    path = str(tmp_path / "t.owt")
    write_owt(path, tensors, meta, channel_axes={"a.weight": 1})
    meta2, out = read_owt(path)
    assert meta2 == meta
    assert list(out) == list(tensors)  # order preserved
    for k in tensors:
        np.testing.assert_array_equal(out[k], tensors[k])
        assert out[k].dtype == tensors[k].dtype


def test_alignment(tmp_path):
    """Every tensor offset must be 64-byte aligned in the data region."""
    import json
    tensors = {f"t{i}": np.ones(i + 1, np.float32) for i in range(5)}
    path = str(tmp_path / "a.owt")
    write_owt(path, tensors)
    raw = open(path, "rb").read()
    assert raw[:4] == MAGIC
    mlen = int.from_bytes(raw[4:8], "little")
    manifest = json.loads(raw[8:8 + mlen])
    for e in manifest["tensors"]:
        assert e["offset"] % 64 == 0


def test_rejects_bad_dtype(tmp_path):
    with pytest.raises(ValueError):
        write_owt(str(tmp_path / "b.owt"), {"x": np.ones(3, np.float64)})


def test_empty_meta_and_scalar_shape(tmp_path):
    path = str(tmp_path / "c.owt")
    write_owt(path, {"s": np.float32(3.5).reshape(())})
    meta, out = read_owt(path)
    assert meta == {}
    assert out["s"].shape == ()
    assert out["s"] == np.float32(3.5)
