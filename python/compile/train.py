"""Build-time training of the microllama checkpoints (the paper's pretrained
model substitutes).

Runs once from ``make artifacts``; the resulting ``.owt`` checkpoints and
token splits are everything the Rust runtime needs — Python never runs again
after this.

Outputs per model size (s/m/l) under artifacts/:
    model_<size>.owt          trained weights + config + loss curve meta
    tokens_<size>_eval.owt    held-out eval sequences (token ids, i32)
    tokens_<size>_fisher.owt  Fisher-estimation sequences (train domain)
    tokens_<size>_xdom.owt    cross-domain sequences (fig. 30)
    tokens_<size>_train.owt   a training-domain batch pool for QAT
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .model import CONFIGS, adam_step, ce_loss, init_params
from .owt import write_owt

# Per-size training budget: (steps, batch). Small on purpose — this is a
# CPU-built substrate; enough for structured, heavy-tailed weights.
BUDGET = {"s": (400, 32), "m": (350, 16), "l": (200, 12)}
SPLITS = {"eval": (64, 101), "fisher": (64, 202), "train": (96, 303)}


def channel_axes_for(shapes: dict) -> dict:
    """Output-channel axis per tensor (axis 1 for (in, out) projections,
    axis 1 for the (vocab, d) embedding's model dim — matching how channel
    scaling is applied in the paper: one scale per output channel)."""
    return {name: 1 for name, shape in shapes.items() if len(shape) == 2}


def train_one(size: str, out_dir: str, seed: int = 0) -> None:
    cfg = CONFIGS[size]
    steps, batch = BUDGET[size]
    corpus = data.Corpus(cfg.vocab, domain=0)
    rng = np.random.default_rng(404 + seed)

    params = init_params(cfg, jax.random.PRNGKey(seed))
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(p) for k, p in params.items()}

    base_lr = 3e-3

    @jax.jit
    def step_fn(params, m, v, step, tokens, lr):
        return adam_step(
            lambda p: ce_loss(cfg, p, tokens), params, m, v, step, lr
        )

    losses = []
    t0 = time.time()
    for step in range(steps):
        tokens = jnp.asarray(corpus.sample(rng, batch, cfg.seq_len))
        # cosine decay with short warmup
        warm = min(1.0, (step + 1) / 20)
        lr = base_lr * warm * 0.5 * (1 + np.cos(np.pi * step / steps))
        params, m, v, loss = step_fn(
            params, m, v, jnp.float32(step), tokens, jnp.float32(lr)
        )
        losses.append(float(loss))
        if step % 50 == 0 or step == steps - 1:
            print(f"[train {size}] step {step:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)", flush=True)

    shapes = cfg.param_shapes()
    np_params = {k: np.asarray(params[k], np.float32) for k in shapes}
    meta = {
        "kind": "microllama-checkpoint",
        "config": {
            "name": cfg.name, "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads, "d_ff": cfg.d_ff,
            "seq_len": cfg.seq_len, "n_params": cfg.n_params(),
        },
        "train": {
            "steps": steps, "batch": batch, "seed": seed,
            "loss_first": losses[0], "loss_last": losses[-1],
            "loss_curve_every50": losses[::50],
        },
    }
    write_owt(f"{out_dir}/model_{size}.owt", np_params, meta,
              channel_axes_for(shapes))
    print(f"[train {size}] wrote model_{size}.owt "
          f"({cfg.n_params()} params, loss {losses[0]:.3f} -> {losses[-1]:.3f})")

    for split, (n_seq, split_seed) in SPLITS.items():
        toks = data.make_split(cfg.vocab, 0, split_seed, n_seq, cfg.seq_len)
        write_owt(f"{out_dir}/tokens_{size}_{split}.owt", {"tokens": toks},
                  {"kind": "tokens", "split": split, "domain": 0})
    xdom = data.make_split(cfg.vocab, 1, 505, SPLITS["eval"][0], cfg.seq_len)
    write_owt(f"{out_dir}/tokens_{size}_xdom.owt", {"tokens": xdom},
              {"kind": "tokens", "split": "xdom", "domain": 1})


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--sizes", default="s,m,l")
    args = ap.parse_args()
    for size in args.sizes.split(","):
        train_one(size, args.out)


if __name__ == "__main__":
    main()
