"""Synthetic corpus generator — the WikiText-103 substitute (DESIGN.md).

A seeded sparse-Markov language over ``vocab`` tokens with Zipfian marginals:
each token has a small set of preferred successors (Dirichlet-weighted), and
with probability ``1 - mix`` the next token falls back to the Zipf unigram.
This gives a corpus with (a) genuinely learnable structure, so a few hundred
training steps produce a model far from init whose weight tensors show the
heavy-tailed statistics the paper relies on (fig. 25), and (b) a held-out
distribution for teacher-forced evaluation.

Two independent "domains" (different structure seeds) support the fig. 30
cross-domain Fisher experiment.
"""

import numpy as np


class Corpus:
    """Seeded synthetic corpus; ``domain`` selects the transition structure."""

    def __init__(self, vocab: int, domain: int = 0, branching: int = 8,
                 mix: float = 0.75, zipf_a: float = 1.2):
        self.vocab = vocab
        rng = np.random.default_rng(1234 + 7919 * domain)
        # Zipf unigram over the vocab (normalised).
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        self.unigram = ranks ** -zipf_a
        self.unigram /= self.unigram.sum()
        # Sparse successor structure: per-token preferred next tokens.
        self.succ = rng.integers(0, vocab, size=(vocab, branching))
        w = rng.dirichlet(np.ones(branching) * 0.5, size=vocab)
        self.succ_cumw = np.cumsum(w, axis=1)
        self.unigram_cum = np.cumsum(self.unigram)
        self.mix = mix

    def _unigram_draw(self, rng: np.random.Generator, n: int) -> np.ndarray:
        return np.searchsorted(self.unigram_cum, rng.random(n))

    def sample(self, rng: np.random.Generator, n_seq: int,
               seq_len: int) -> np.ndarray:
        """(n_seq, seq_len) int32 token batch (fully vectorised per step)."""
        out = np.empty((n_seq, seq_len), np.int64)
        out[:, 0] = self._unigram_draw(rng, n_seq)
        for t in range(1, seq_len):
            prev = out[:, t - 1]
            use_struct = rng.random(n_seq) < self.mix
            # structured step: inverse-cdf draw among preferred successors
            cumw = self.succ_cumw[prev]  # (n_seq, branching)
            pick = (rng.random(n_seq)[:, None] > cumw).sum(axis=1)
            pick = np.minimum(pick, cumw.shape[1] - 1)
            choice = self.succ[prev, pick]
            fallback = self._unigram_draw(rng, n_seq)
            out[:, t] = np.where(use_struct, choice, fallback)
        return out.astype(np.int32)


def make_split(vocab: int, domain: int, seed: int, n_seq: int,
               seq_len: int) -> np.ndarray:
    """Deterministic named split (train/eval/fisher differ only by seed)."""
    corpus = Corpus(vocab, domain=domain)
    rng = np.random.default_rng(seed)
    return corpus.sample(rng, n_seq, seq_len)
