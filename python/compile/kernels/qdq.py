"""Pallas kernel: fused block-scaled quantise->dequantise (the paper's hot
spot).

Every direct-cast evaluation and every QAT step pushes all model weights
through this operation, so it is the L1 compute kernel of the stack.  The
kernel is format-agnostic: the (sorted, normalised) codebook arrives as an
operand, so one compiled kernel serves INT / float / NF4 / SF4 / cube-root
Normal / Laplace / Student-t element formats — only the codebook changes.

TPU mapping (see DESIGN.md "Hardware adaptation"):

* the data block per grid step is a ``(ROWS_PER_STEP, B)`` f32 tile; with the
  paper-default B=128 this matches the TPU lane width, so the absmax is a
  lane reduction and the tile is (8, 128)-aligned for VMEM,
* the codebook (K <= 32 values, <= 128 B) is broadcast to every grid step and
  lives in VMEM for the whole kernel,
* nearest-codepoint assignment is K-1 vectorised compares accumulated into an
  index (branchless, no gather for the search) followed by a single small
  gather from the codebook — a pure VPU kernel, the MXU is untouched,
* HBM<->VMEM double-buffering falls out of the grid/BlockSpec schedule.

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered through the interpreter to plain HLO,
which is exactly what the Rust runtime loads (see python/compile/aot.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows of blocks processed per grid step. 8 f32 sublanes x B lanes per tile.
ROWS_PER_STEP = 8


def _qdq_kernel(x_ref, cb_ref, o_ref, *, mode: str, scale_bf16: bool):
    """Kernel body: one (ROWS_PER_STEP, B) tile of blocks.

    x_ref: (R, B) f32 tile of input blocks.
    cb_ref: (K,) f32 sorted codebook (normalised space), whole-array spec.
    o_ref: (R, B) f32 dequantised output tile.
    """
    x = x_ref[...]
    cb = cb_ref[...]

    # --- block statistic (lane reduction) ---------------------------------
    if mode == "absmax":
        s = jnp.max(jnp.abs(x), axis=-1)
    else:  # rms
        s = jnp.sqrt(jnp.mean(jnp.square(x), axis=-1))
    s = jnp.where(s == 0.0, 1.0, s)

    # --- bfloat16 round-away scale emulation (integer ops, branchless) ----
    if scale_bf16:
        u = s.view(jnp.uint32)
        upper = (u >> 16) + ((u & jnp.uint32(0xFFFF)) != 0).astype(jnp.uint32)
        s = (upper << 16).view(jnp.float32)

    y = x / s[:, None]

    # --- branchless nearest-codepoint search ------------------------------
    # index = #  of midpoints <= y ; midpoints m_k = (cb[k] + cb[k+1]) / 2.
    # K-1 broadcast compares, accumulated as int32 — no sorted search, no
    # data-dependent control flow, vectorises across the whole tile.
    mids = (cb[1:] + cb[:-1]) * 0.5
    idx = jnp.sum(
        (y[:, :, None] >= mids[None, None, :]).astype(jnp.int32), axis=-1
    )

    o_ref[...] = cb[idx] * s[:, None]


@functools.partial(jax.jit, static_argnames=("mode", "scale_bf16"))
def qdq_block(
    x: jnp.ndarray,
    codebook: jnp.ndarray,
    mode: str = "absmax",
    scale_bf16: bool = True,
) -> jnp.ndarray:
    """Fused block quantise->dequantise via Pallas.

    Args:
        x: (n_blocks, B) float32; n_blocks must be a multiple of
           ROWS_PER_STEP (callers pad; model tensors satisfy this naturally).
        codebook: (K,) sorted float32 codepoints in normalised space.
        mode: "absmax" or "rms" block statistic.
        scale_bf16: emulate bfloat16 round-away scale storage.

    Returns:
        (n_blocks, B) float32 dequantised data.
    """
    n_blocks, block = x.shape
    if n_blocks % ROWS_PER_STEP != 0:
        raise ValueError(
            f"n_blocks={n_blocks} must be a multiple of {ROWS_PER_STEP}"
        )
    grid = (n_blocks // ROWS_PER_STEP,)
    return pl.pallas_call(
        functools.partial(_qdq_kernel, mode=mode, scale_bf16=scale_bf16),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS_PER_STEP, block), lambda i: (i, 0)),
            # Whole codebook broadcast to every grid step.
            pl.BlockSpec(codebook.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((ROWS_PER_STEP, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x, codebook)


# ---------------------------------------------------------------------------
# Straight-through-estimator wrapper, used by the QAT training graph (L2).
# Forward: qdq via the Pallas kernel. Backward: identity on x, zero on the
# codebook (centroids are fixed during QAT, as in the paper's procedure).
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def qdq_ste(x, codebook, mode: str = "absmax", scale_bf16: bool = True):
    return qdq_block(x, codebook, mode=mode, scale_bf16=scale_bf16)


def _qdq_ste_fwd(x, codebook, mode, scale_bf16):
    return qdq_block(x, codebook, mode=mode, scale_bf16=scale_bf16), None


def _qdq_ste_bwd(mode, scale_bf16, _res, g):
    return g, None


qdq_ste.defvjp(_qdq_ste_fwd, _qdq_ste_bwd)


def qdq_tensor(
    w: jnp.ndarray,
    codebook: jnp.ndarray,
    block: int = 128,
    mode: str = "absmax",
    scale_bf16: bool = True,
    ste: bool = False,
) -> jnp.ndarray:
    """Quantise->dequantise an arbitrary-shaped weight tensor.

    Flattens to blocks of ``block`` elements (padding the tail block with
    zeros, which quantise exactly under any codebook containing 0 and are
    discarded on reshape), runs the Pallas kernel, restores the shape.
    """
    flat = w.reshape(-1)
    n = flat.shape[0]
    n_blocks = -(-n // block)
    # pad to a multiple of ROWS_PER_STEP rows of full blocks
    rows = -(-n_blocks // ROWS_PER_STEP) * ROWS_PER_STEP
    padded = jnp.zeros((rows * block,), jnp.float32).at[:n].set(flat)
    fn = qdq_ste if ste else qdq_block
    out = fn(padded.reshape(rows, block), codebook, mode, scale_bf16)
    return out.reshape(-1)[:n].reshape(w.shape)
