"""Pure-jnp reference (oracle) for the fused block-scaled quantise->dequantise
kernel.

This is the correctness ground truth for the Pallas kernel in ``qdq.py``; the
Rust implementation in ``rust/src/quant`` is cross-checked against the lowered
Pallas HLO as well (see rust/tests/qdq_cross.rs), closing the three-way loop

        ref.py  ==  pallas qdq.py  ==  rust quant::qdq

The quantiser semantics follow the paper ("Optimal Formats for Weight
Quantisation", sec. 2.1):

* the input is viewed as ``(n_blocks, B)``; each block is scaled by a single
  statistic (absmax or RMS),
* the scale itself is stored in a reduced-precision format; we model the
  paper's default, bfloat16 with *round-away* rounding (appendix, fig. 19:
  round-away avoids clipping the block maximum outside [-1, 1]),
* scaled elements are rounded to the nearest codepoint of a sorted codebook
  ``Q`` (ties resolve to the upper codepoint, matching ``jnp.searchsorted``
  over midpoints),
* dequantisation multiplies back by the scale.
"""

from typing import Literal

import jax.numpy as jnp

ScaleMode = Literal["absmax", "rms"]


def round_scale_bf16_away(x: jnp.ndarray) -> jnp.ndarray:
    """Round a positive float32 scale to bfloat16, away from zero.

    bfloat16 is float32 with the low 16 mantissa bits dropped; rounding away
    from zero for positive values means incrementing the upper half whenever
    any dropped bit is set.  (Scales are strictly positive here: absmax == 0
    blocks are handled by the caller mapping scale 0 -> 1.)
    """
    u = jnp.asarray(x, jnp.float32).view(jnp.uint32)
    upper = u >> 16
    sticky = (u & jnp.uint32(0xFFFF)) != 0
    upper = upper + sticky.astype(jnp.uint32)
    return (upper << 16).view(jnp.float32)


def block_scale(x: jnp.ndarray, mode: ScaleMode) -> jnp.ndarray:
    """Per-row scale statistic for ``x`` of shape (n_blocks, B)."""
    if mode == "absmax":
        s = jnp.max(jnp.abs(x), axis=-1)
    elif mode == "rms":
        s = jnp.sqrt(jnp.mean(jnp.square(x), axis=-1))
    else:  # pragma: no cover - guarded by typing
        raise ValueError(f"unknown scale mode {mode!r}")
    # Zero blocks would divide by zero; the dequantised result is exact zero
    # for any codebook containing 0 and harmless otherwise, matching Rust.
    return jnp.where(s == 0.0, 1.0, s)


def quantise_indices(y: jnp.ndarray, codebook: jnp.ndarray) -> jnp.ndarray:
    """Nearest-codepoint indices for scaled data ``y`` (any shape).

    ``codebook`` must be sorted ascending.  Nearest-neighbour assignment over
    a sorted codebook == searchsorted against interval midpoints (ties go to
    the upper codepoint).
    """
    mids = (codebook[1:] + codebook[:-1]) * 0.5
    return jnp.searchsorted(mids, y, side="right").astype(jnp.int32)


def qdq_block_ref(
    x: jnp.ndarray,
    codebook: jnp.ndarray,
    mode: ScaleMode = "absmax",
    scale_bf16: bool = True,
) -> jnp.ndarray:
    """Reference fused quantise->dequantise.

    Args:
        x: (n_blocks, B) float32 data.
        codebook: (K,) sorted float32 codepoints (normalised space).
        mode: block statistic, "absmax" or "rms".
        scale_bf16: store the scale in bfloat16 round-away (paper default).

    Returns:
        (n_blocks, B) float32 dequantised data.
    """
    x = jnp.asarray(x, jnp.float32)
    s = block_scale(x, mode)
    if scale_bf16:
        s = round_scale_bf16_away(s)
    y = x / s[:, None]
    idx = quantise_indices(y, codebook)
    return codebook[idx] * s[:, None]
