"""``.owt`` — the Optimal-Weight-Formats tensor container.

A deliberately simple binary format shared between the Python build path and
the Rust runtime (rust/src/tensorstore mirrors this exactly):

    bytes 0..4    magic  b"OWT1"
    bytes 4..8    u32 LE manifest length  (M)
    bytes 8..8+M  manifest, UTF-8 JSON
    8+M..        data region; every tensor offset is relative to the region
                  start and 64-byte aligned

Manifest schema::

    {
      "meta":    { ...free-form string/number map... },
      "tensors": [
        {"name": str, "dtype": "f32"|"i32", "shape": [int],
         "offset": int, "channel_axis": int|null},
        ...
      ]
    }

``channel_axis`` marks the output-channel axis used by channel-scaled
formats (null for 1-D tensors).
"""

import json
from typing import Dict, Optional, Tuple

import numpy as np

MAGIC = b"OWT1"
_ALIGN = 64

_DTYPES = {"f32": np.float32, "i32": np.int32}


def write_owt(path: str, tensors: Dict[str, np.ndarray],
              meta: Optional[dict] = None,
              channel_axes: Optional[Dict[str, int]] = None) -> None:
    """Write tensors (insertion order preserved) with optional metadata."""
    channel_axes = channel_axes or {}
    entries = []
    offset = 0
    blobs = []
    for name, arr in tensors.items():
        if arr.dtype == np.float32:
            dtype = "f32"
        elif arr.dtype == np.int32:
            dtype = "i32"
        else:
            raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
        data = np.ascontiguousarray(arr).tobytes()
        entries.append({
            "name": name,
            "dtype": dtype,
            "shape": list(arr.shape),
            "offset": offset,
            "channel_axis": channel_axes.get(name),
        })
        blobs.append(data)
        offset += len(data)
        pad = (-offset) % _ALIGN
        if pad:
            blobs.append(b"\0" * pad)
            offset += pad
    manifest = json.dumps(
        {"meta": meta or {}, "tensors": entries}, indent=None
    ).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(len(manifest).to_bytes(4, "little"))
        f.write(manifest)
        for b in blobs:
            f.write(b)


def read_owt(path: str) -> Tuple[dict, Dict[str, np.ndarray]]:
    """Read a container; returns (meta, name->array)."""
    with open(path, "rb") as f:
        raw = f.read()
    assert raw[:4] == MAGIC, f"{path}: bad magic"
    mlen = int.from_bytes(raw[4:8], "little")
    manifest = json.loads(raw[8:8 + mlen])
    base = 8 + mlen
    out = {}
    for e in manifest["tensors"]:
        dt = _DTYPES[e["dtype"]]
        n = int(np.prod(e["shape"])) if e["shape"] else 1
        start = base + e["offset"]
        arr = np.frombuffer(raw, dt, count=n, offset=start)
        out[e["name"]] = arr.reshape(e["shape"]).copy()
    return manifest["meta"], out
