"""AOT export: lower every L2 graph to HLO **text** for the Rust runtime.

HLO text (not ``lowered.compile().serialize()``/proto bytes) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which xla_extension 0.5.1 (the version behind the Rust ``xla`` crate)
rejects; the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.

Also writes ``artifacts/manifest.json`` describing, for every artifact, the
exact flattened input order (name/dtype/shape) and output order, which the
Rust runtime (rust/src/runtime) uses to marshal literals.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels.qdq import qdq_block
from .model import CONFIGS, fisher_batch, empirical_fisher_batch, \
    kl_to_ref, logits_fn, adam_step

# Evaluation/fisher/QAT batch sizes (sequences per PJRT call).
FWD_BATCH = {"s": 16, "m": 16, "l": 8}
FISHER_BATCH = {"s": 8, "m": 8, "l": 4}
QAT_BATCH = 8
QDQ_BLOCKS, QDQ_BLOCK = 4096, 128
CODEBOOK_K = 16  # 4-bit LUT; smaller formats pad by duplicating codepoints


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_of(x):
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


def _flat_io(args, out):
    """Flattened (inputs, outputs) description for the manifest."""

    def describe(tree, prefix):
        leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
        out = []
        for path, leaf in leaves_with_paths:
            suffix = "".join(
                f".{p.key}" if hasattr(p, "key") else f".{p.idx}"
                for p in path
            )
            out.append({
                "name": prefix + suffix,
                "dtype": str(leaf.dtype),
                "shape": list(leaf.shape),
            })
        return out

    ins = []
    for i, a in enumerate(args):
        ins += describe(a, f"arg{i}")
    return ins, describe(out, "out")


def export(fn, args, path: str, name: str, manifest: list) -> None:
    """Lower ``fn(*args)`` (specs), write HLO text, record manifest entry."""
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(path, fname), "w") as f:
        f.write(text)
    out_spec = jax.eval_shape(fn, *args)
    ins, outs = _flat_io(args, out_spec)
    manifest.append({
        "name": name, "file": fname, "inputs": ins, "outputs": outs,
    })
    print(f"[aot] {fname}: {len(text)} chars, "
          f"{len(ins)} inputs, {len(outs)} outputs", flush=True)


def param_specs(cfg):
    return {
        k: jax.ShapeDtypeStruct(s, jnp.float32)
        for k, s in cfg.param_shapes().items()
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--sizes", default="s,m,l")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest: list = []

    # --- standalone fused block qdq (the L1 kernel as its own artifact) ---
    xspec = jax.ShapeDtypeStruct((QDQ_BLOCKS, QDQ_BLOCK), jnp.float32)
    cbspec = jax.ShapeDtypeStruct((CODEBOOK_K,), jnp.float32)
    export(lambda x, cb: (qdq_block(x, cb, mode="absmax"),),
           (xspec, cbspec), args.out, "qdq_block_absmax", manifest)
    export(lambda x, cb: (qdq_block(x, cb, mode="rms"),),
           (xspec, cbspec), args.out, "qdq_block_rms", manifest)

    for size in args.sizes.split(","):
        cfg = CONFIGS[size]
        pspec = param_specs(cfg)
        toks = jax.ShapeDtypeStruct((FWD_BATCH[size], cfg.seq_len), jnp.int32)

        # forward logits — used for eval (direct-cast KL) and as reference
        export(lambda p, t, cfg=cfg: (logits_fn(cfg, p, t),),
               (pspec, toks), args.out, f"model_fwd_{size}", manifest)

        # Fisher batch (sampled labels) and empirical-Fisher batch
        ftoks = jax.ShapeDtypeStruct(
            (FISHER_BATCH[size], cfg.seq_len), jnp.int32
        )
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        export(lambda p, t, k, cfg=cfg: fisher_batch(cfg, p, t, k),
               (pspec, ftoks, key), args.out, f"fisher_{size}", manifest)
        if size == "m":
            export(lambda p, t, cfg=cfg: empirical_fisher_batch(cfg, p, t),
                   (pspec, ftoks), args.out, "fisher_emp_m", manifest)

    # --- QAT step (model m): STE quantised fwd + full-KL loss + Adam -------
    cfg = CONFIGS["m"]
    pspec = param_specs(cfg)
    qtoks = jax.ShapeDtypeStruct((QAT_BATCH, cfg.seq_len), jnp.int32)
    rlog = jax.ShapeDtypeStruct((QAT_BATCH, cfg.seq_len, cfg.vocab),
                                jnp.float32)
    cb = jax.ShapeDtypeStruct((CODEBOOK_K,), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)

    def qat_step(params, m, v, step, tokens, ref_logits, codebook, lr,
                 block, mode):
        return adam_step(
            lambda p: kl_to_ref(cfg, p, tokens, ref_logits, codebook,
                                block, mode),
            params, m, v, step, lr,
        )

    for tag, block, mode in (
        ("block128_absmax", 128, "absmax"),
        ("tensor_rms", 0, "rms"),
    ):
        export(
            lambda p, m, v, s, t, r, c, lr, block=block, mode=mode:
                qat_step(p, m, v, s, t, r, c, lr, block, mode),
            (pspec, pspec, pspec, scalar, qtoks, rlog, cb, scalar),
            args.out, f"qat_step_m_{tag}", manifest,
        )

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump({"artifacts": manifest}, f, indent=1)
    print(f"[aot] manifest.json: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
