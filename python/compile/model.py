"""L2: "microllama" — a from-scratch JAX transformer language model.

This is the substitute substrate for the paper's Llama/Qwen/Gemma/Phi
checkpoints (see DESIGN.md, "Substitutions").  Architecture mirrors Llama 3:
token embedding -> N x (RMSNorm -> GQA attention with RoPE -> RMSNorm ->
SwiGLU MLP) -> final RMSNorm -> untied LM head.

Everything is a pure function over a flat ``dict[str, jnp.ndarray]`` of
parameters with Llama-style names (``layers.0.self_attn.q_proj`` etc.), so
the Rust side addresses tensors by the same names the paper's figures use.

The QAT forward pass (``qat_logits``) routes every 2-D weight through the
Pallas STE quantise->dequantise kernel (L1), which is how the L1 kernel lowers
into the exported HLO graphs.
"""

import dataclasses
import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from .kernels.qdq import qdq_tensor

Params = Dict[str, jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Config:
    """microllama hyper-parameters; S/M/L presets below."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    seq_len: int
    rope_theta: float = 10000.0

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    def param_shapes(self) -> Dict[str, tuple]:
        """Deterministic name -> shape map (insertion order = layer order)."""
        d, h, kv, dh = self.d_model, self.n_heads, self.n_kv_heads, self.d_head
        shapes: Dict[str, tuple] = {"embed_tokens": (self.vocab, d)}
        for i in range(self.n_layers):
            p = f"layers.{i}"
            shapes[f"{p}.input_layernorm"] = (d,)
            shapes[f"{p}.self_attn.q_proj"] = (d, h * dh)
            shapes[f"{p}.self_attn.k_proj"] = (d, kv * dh)
            shapes[f"{p}.self_attn.v_proj"] = (d, kv * dh)
            shapes[f"{p}.self_attn.o_proj"] = (h * dh, d)
            shapes[f"{p}.post_attention_layernorm"] = (d,)
            shapes[f"{p}.mlp.gate_proj"] = (d, self.d_ff)
            shapes[f"{p}.mlp.up_proj"] = (d, self.d_ff)
            shapes[f"{p}.mlp.down_proj"] = (self.d_ff, d)
        shapes["final_norm"] = (d,)
        shapes["lm_head"] = (d, self.vocab)
        return shapes

    def n_params(self) -> int:
        return sum(
            functools.reduce(lambda a, b: a * b, s, 1)
            for s in self.param_shapes().values()
        )


CONFIGS = {
    # Small: the "many model families" stand-in, fast enough to sweep widely.
    "s": Config("s", vocab=512, d_model=64, n_layers=2, n_heads=4,
                n_kv_heads=2, d_ff=192, seq_len=128),
    # Medium: the headline model (fig. 1 analogue).
    "m": Config("m", vocab=1024, d_model=128, n_layers=4, n_heads=8,
                n_kv_heads=2, d_ff=384, seq_len=128),
    # Large: scaling check.
    "l": Config("l", vocab=2048, d_model=192, n_layers=6, n_heads=8,
                n_kv_heads=4, d_ff=576, seq_len=128),
}


def init_params(cfg: Config, key: jax.Array) -> Params:
    """Scaled-normal init (norm gains at 1)."""
    params: Params = {}
    for name, shape in cfg.param_shapes().items():
        key, sub = jax.random.split(key)
        if name.endswith("layernorm") or name == "final_norm":
            params[name] = jnp.ones(shape, jnp.float32)
        elif name == "embed_tokens":
            params[name] = jax.random.normal(sub, shape, jnp.float32) * 0.02
        else:
            fan_in = shape[0]
            params[name] = jax.random.normal(sub, shape, jnp.float32) * (
                fan_in ** -0.5
            )
    return params


def _rms_norm(x: jnp.ndarray, gain: jnp.ndarray, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * gain


def _rope(x: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding over (batch, seq, heads, d_head)."""
    _, seq, _, dh = x.shape
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = jnp.arange(seq, dtype=jnp.float32)[:, None] * freqs[None, :]
    cos = jnp.cos(angles)[None, :, None, :]
    sin = jnp.sin(angles)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def _attention(cfg: Config, params: Params, prefix: str, x: jnp.ndarray,
               wmap: Callable[[str, jnp.ndarray], jnp.ndarray]):
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head

    def proj(name, width):
        w = wmap(f"{prefix}.{name}", params[f"{prefix}.{name}"])
        return (x @ w).reshape(b, s, width, dh)

    q = _rope(proj("self_attn.q_proj", h), cfg.rope_theta)
    k = _rope(proj("self_attn.k_proj", kv), cfg.rope_theta)
    v = proj("self_attn.v_proj", kv)
    # GQA: repeat kv heads across the query-head group.
    group = h // kv
    k = jnp.repeat(k, group, axis=2)
    v = jnp.repeat(v, group, axis=2)

    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (dh ** 0.5)
    mask = jnp.tril(jnp.ones((s, s), jnp.bool_))
    logits = jnp.where(mask[None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, s, h * dh)
    wo = wmap(f"{prefix}.self_attn.o_proj", params[f"{prefix}.self_attn.o_proj"])
    return out @ wo


def _mlp(params: Params, prefix: str, x: jnp.ndarray,
         wmap: Callable[[str, jnp.ndarray], jnp.ndarray]):
    gate = wmap(f"{prefix}.mlp.gate_proj", params[f"{prefix}.mlp.gate_proj"])
    up = wmap(f"{prefix}.mlp.up_proj", params[f"{prefix}.mlp.up_proj"])
    down = wmap(f"{prefix}.mlp.down_proj", params[f"{prefix}.mlp.down_proj"])
    return (jax.nn.silu(x @ gate) * (x @ up)) @ down


def logits_fn(cfg: Config, params: Params, tokens: jnp.ndarray,
              wmap: Optional[Callable[[str, jnp.ndarray], jnp.ndarray]] = None
              ) -> jnp.ndarray:
    """Forward pass: (batch, seq) int32 tokens -> (batch, seq, vocab) f32.

    ``wmap(name, w)`` is applied to every weight matrix on the way in; the
    identity for the reference model, the STE quantiser for QAT.
    """
    if wmap is None:
        wmap = lambda _, w: w
    emb = wmap("embed_tokens", params["embed_tokens"])
    x = emb[tokens]
    for i in range(cfg.n_layers):
        p = f"layers.{i}"
        hn = _rms_norm(x, params[f"{p}.input_layernorm"])
        x = x + _attention(cfg, params, p, hn, wmap)
        hn = _rms_norm(x, params[f"{p}.post_attention_layernorm"])
        x = x + _mlp(params, p, hn, wmap)
    x = _rms_norm(x, params["final_norm"])
    head = wmap("lm_head", params["lm_head"])
    return x @ head


def qat_wmap(codebook: jnp.ndarray, block: int = 128, mode: str = "absmax"
             ) -> Callable[[str, jnp.ndarray], jnp.ndarray]:
    """Weight map routing every >=2-D tensor through the Pallas STE qdq.

    1-D tensors (RMSNorm gains) stay in full precision, as in the paper's QAT
    setup; ``block <= 0`` means per-tensor scaling (single block).
    """

    def wmap(name: str, w: jnp.ndarray) -> jnp.ndarray:
        if w.ndim < 2:
            return w
        b = block if block > 0 else w.size
        return qdq_tensor(w, codebook, block=b, mode=mode, ste=True)

    return wmap


def qat_logits(cfg: Config, params: Params, tokens: jnp.ndarray,
               codebook: jnp.ndarray, block: int = 128,
               mode: str = "absmax") -> jnp.ndarray:
    return logits_fn(cfg, params, tokens, qat_wmap(codebook, block, mode))


# ---------------------------------------------------------------------------
# Losses / metrics used by the exported graphs
# ---------------------------------------------------------------------------


def ce_loss(cfg: Config, params: Params, tokens: jnp.ndarray,
            wmap=None) -> jnp.ndarray:
    """Next-token cross entropy (mean nats/token) for training."""
    logits = logits_fn(cfg, params, tokens, wmap)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def kl_to_ref(cfg: Config, params: Params, tokens: jnp.ndarray,
              ref_logits: jnp.ndarray, codebook: jnp.ndarray,
              block: int, mode: str) -> jnp.ndarray:
    """Full KL(ref || student) per token, averaged — the QAT loss."""
    logits = qat_logits(cfg, params, tokens, codebook, block, mode)
    p = jax.nn.softmax(ref_logits, axis=-1)
    logp = jax.nn.log_softmax(ref_logits, axis=-1)
    logq = jax.nn.log_softmax(logits, axis=-1)
    return jnp.mean(jnp.sum(p * (logp - logq), axis=-1))


def fisher_batch(cfg: Config, params: Params, tokens: jnp.ndarray,
                 key: jax.Array) -> Params:
    """Per-parameter squared-gradient accumulation for one batch (eq. 8).

    Labels are *sampled from the model's own predictive distribution* (not
    the data), estimating the Fisher rather than the empirical Fisher; the
    gradient is computed per sequence (vmap over the batch) and squared
    before summation. The paper squares per token; per-sequence is the
    documented substitution (DESIGN.md) — it preserves the inter-tensor
    structure used by eq. (5).
    """
    logits = logits_fn(cfg, params, tokens)  # (b, s, v)
    sampled = jax.random.categorical(key, logits[:, :-1])  # (b, s-1)

    def seq_loss(p: Params, toks: jnp.ndarray, labels: jnp.ndarray):
        lg = logits_fn(cfg, p, toks[None])[0, :-1]
        logp = jax.nn.log_softmax(lg, axis=-1)
        return -jnp.sum(
            jnp.take_along_axis(logp, labels[:, None], axis=-1)
        )

    grads = jax.vmap(lambda t, l: jax.grad(seq_loss)(params, t, l))(
        tokens, sampled
    )
    return {k: jnp.sum(jnp.square(g), axis=0) for k, g in grads.items()}


def empirical_fisher_batch(cfg: Config, params: Params,
                           tokens: jnp.ndarray) -> Params:
    """Empirical-Fisher variant (dataset labels), for the fig. 27 analogue."""

    def seq_loss(p: Params, toks: jnp.ndarray):
        lg = logits_fn(cfg, p, toks[None])[0, :-1]
        logp = jax.nn.log_softmax(lg, axis=-1)
        return -jnp.sum(
            jnp.take_along_axis(logp, toks[1:, None], axis=-1)
        )

    grads = jax.vmap(lambda t: jax.grad(seq_loss)(params, t))(tokens)
    return {k: jnp.sum(jnp.square(g), axis=0) for k, g in grads.items()}


# ---------------------------------------------------------------------------
# Adam training step (used by train.py and the exported QAT step)
# ---------------------------------------------------------------------------


def adam_step(loss_fn, params: Params, m: Params, v: Params, step,
              lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8):
    """One Adam update; ``step`` is a 0-based scalar (traced or concrete)."""
    loss, grads = jax.value_and_grad(loss_fn)(params)
    m = {k: b1 * m[k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * v[k] + (1 - b2) * jnp.square(grads[k]) for k in params}
    t = step + 1
    mhat = {k: m[k] / (1 - b1 ** t) for k in params}
    vhat = {k: v[k] / (1 - b2 ** t) for k in params}
    new = {
        k: params[k] - lr * mhat[k] / (jnp.sqrt(vhat[k]) + eps)
        for k in params
    }
    return new, m, v, loss
