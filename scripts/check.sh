#!/usr/bin/env bash
# CI entry point: tier-1 build + tests, a bench smoke run at tiny n (which
# gates the LUT-vs-reference quantisation equivalence contract AND the
# decode_into-vs-decode_ref bit-exactness contract before any timing),
# an `owf pack`/unpack bit-exactness gate at tiny n (packed decode must
# be bit-identical to the in-memory pipeline, for both entropy codecs
# and for every scheme family the sweep grammar produces — including
# `:rot` and `grid`, the OWQ2 forms), a fault-injection gate (a flipped
# bit in every section class must drive `owf fsck` to a nonzero exit
# with a typed verdict — on base, rot and grid containers — and
# `owf serve-bench` must survive injected transient EIO + payload flips),
# a fractional-allocation gate (an OWQ3 store packed at a non-lattice
# 3.3-bit budget must inspect --verify bit-exact, print its per-tensor
# scheme mix, fail fsck typed on a block_schemes bit flip, and serve),
# an overload gate (a one-permit, depth-2, 50ms-deadline serve-bench under
# transient faults must terminate inside a wall-clock timeout with a
# closed stats partition — the no-unbounded-wait backstop),
# then a forced-ISA parity gate (the same container packed under
# OWF_ISA=scalar and under the auto-detected ISA must be byte-identical,
# and each must inspect --verify under the *other* ISA — SIMD kernels
# are contractually bit-exact with their scalar oracles; on a host with
# neither AVX2 nor NEON the detected ISA IS scalar and the gate still
# passes), and an `owf sweep` smoke run over a 12-point grid with
# --resume exercised twice (the second resume must re-run zero points
# and leave the row count unchanged).
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q (OWF_THREADS=4) =="
OWF_THREADS=4 cargo test -q

echo "== bench smoke: LUT/reference + decode bit-exactness gates (n=2^14) =="
# benches/formats.rs asserts bit-exact LUT/reference agreement for every
# benched codebook AND decode_into/decode_ref parity for every benched
# encoding before timing; at 2^14 elements this is a fast gate
OWF_BENCH_N=$((1 << 14)) OWF_THREADS=4 cargo bench --bench formats \
    > /dev/null

BIN=target/release/owf

echo "== owf pack/unpack bit-exactness gate (tiny n, huffman + rans) =="
# pack deterministic synthetic tensors, then prove the packed decode
# bit-identical to the in-memory pipeline (inspect --verify regenerates
# the sim source from the manifest seed and compares recon/sq-err/bits
# to the last bit), and that the concurrent server reports cache stats
PACK_DIR="$(mktemp -d)"
for codec in huffman rans; do
    OWQ="$PACK_DIR/gate_$codec.owq"
    "$BIN" pack --spec 'cbrt-t5@4:block64-absmax:sparse0.01,compress' \
        --sim 96x64,4096 --seed 7 --codec "$codec" --lanes 4 \
        --alloc variable --out "$OWQ"
    "$BIN" inspect "$OWQ" --verify
    SERVE_OUT=$("$BIN" serve-bench "$OWQ" --threads 4 --requests 64)
    echo "$SERVE_OUT"
    echo "$SERVE_OUT" | grep -q 'hit rate' || {
        echo "check.sh: serve-bench ($codec) reported no cache stats" >&2
        exit 1
    }
done

echo "== owf pack gate: OWQ2 scheme families (:rot, grid) =="
# the v1 writer rejected rotated and grid specs outright; the OWQ2
# container must pack them and prove the decode bit-identical through
# the same inspect --verify path (seed re-derivation + inverse rotation
# for :rot, dense-index gather for grid)
for codec in huffman rans; do
    for family in rot grid; do
        case "$family" in
            rot)  SPEC='cbrt-t5@4:block64-absmax:sparse0.01,compress,rot' ;;
            grid) SPEC='grid@4:tensor-rms:compress' ;;
        esac
        OWQ="$PACK_DIR/gate_${family}_$codec.owq"
        "$BIN" pack --spec "$SPEC" --sim 96x64,4096 --seed 7 \
            --codec "$codec" --lanes 4 --out "$OWQ"
        "$BIN" inspect "$OWQ" --verify
    done
done

echo "== owf fsck + fault-injection gate (tiny n) =="
# a clean container must pass fsck (exit 0, 'clean' in the summary)
CLEAN="$PACK_DIR/gate_huffman.owq"
"$BIN" fsck "$CLEAN" | grep -q 'clean' || {
    echo "check.sh: fsck called a clean container damaged" >&2
    exit 1
}
# a single flipped bit in every section class must surface as typed
# damage: fsck exits nonzero and the output names a corrupt/torn verdict
FAULT_DIR="$(mktemp -d)"
for section in codebook scales payload counts outlier_idx outlier_val \
        manifest header; do
    BAD="$FAULT_DIR/bad_$section.owq"
    "$BIN" fault-inject "$CLEAN" --out "$BAD" --section "$section"
    if FSCK_OUT=$("$BIN" fsck "$BAD" 2>&1); then
        echo "check.sh: fsck missed a $section bit flip" >&2
        exit 1
    fi
    echo "$FSCK_OUT" | grep -Eqi 'corrupt|torn|DAMAGED|unreadable' || {
        echo "check.sh: fsck verdict for a $section flip is untyped:" >&2
        echo "$FSCK_OUT" >&2
        exit 1
    }
done
# a torn rename (interrupted atomic write) must read as damage, not data
"$BIN" fault-inject "$CLEAN" --out "$FAULT_DIR/torn.owq" \
    --truncate-frac 0.5
if "$BIN" fsck "$FAULT_DIR/torn.owq" > /dev/null 2>&1; then
    echo "check.sh: fsck accepted a half-written container" >&2
    exit 1
fi

echo "== fault-injection gate over OWQ2 containers (:rot, grid) =="
# the new durable forms carry the same per-section checksums: a flipped
# bit in any populated section of a rot or grid container must surface
for section in codebook scales payload counts outlier_idx outlier_val \
        manifest header; do
    BAD="$FAULT_DIR/rot_$section.owq"
    "$BIN" fault-inject "$PACK_DIR/gate_rot_huffman.owq" --out "$BAD" \
        --section "$section"
    if "$BIN" fsck "$BAD" > /dev/null 2>&1; then
        echo "check.sh: fsck missed a rot-container $section flip" >&2
        exit 1
    fi
done
# grid containers keep scales and outlier sections empty; flip the rest
for section in codebook payload counts manifest header; do
    BAD="$FAULT_DIR/grid_$section.owq"
    "$BIN" fault-inject "$PACK_DIR/gate_grid_huffman.owq" --out "$BAD" \
        --section "$section"
    if "$BIN" fsck "$BAD" > /dev/null 2>&1; then
        echo "check.sh: fsck missed a grid-container $section flip" >&2
        exit 1
    fi
done

echo "== fractional allocation gate (OWQ3: pack 3.3b, verify, fault, serve) =="
# the fractional allocator must hit a non-lattice budget by mixing two
# int schemes per tensor at block granularity; inspect --verify proves
# the packed mixed decode bit-identical to the in-memory mixed pipeline,
# the inspect text must surface the v3 version and the per-tensor mix,
# a flipped bit in the block_schemes id stream must fail fsck typed,
# and the serving stack must serve the v3 store unchanged
FRAC="$PACK_DIR/gate_frac.owq"
"$BIN" pack --spec 'int@4:block64-absmax' --alloc fractional --bits 3.3 \
    --sim 96x64,4096 --seed 7 --codec huffman --lanes 4 --out "$FRAC"
FRAC_OUT=$("$BIN" inspect "$FRAC" --verify)
echo "$FRAC_OUT"
echo "$FRAC_OUT" | grep -q 'OWQ v3' || {
    echo "check.sh: fractional container did not inspect as OWQ v3" >&2
    exit 1
}
echo "$FRAC_OUT" | grep -q 'mix:' || {
    echo "check.sh: fractional inspect printed no per-tensor mix" >&2
    exit 1
}
echo "$FRAC_OUT" | grep -q 'alloc: fractional' || {
    echo "check.sh: fractional alloc record missing from inspect" >&2
    exit 1
}
for section in block_schemes codebook scales payload counts; do
    BAD="$FAULT_DIR/frac_$section.owq"
    "$BIN" fault-inject "$FRAC" --out "$BAD" --section "$section"
    if "$BIN" fsck "$BAD" > /dev/null 2>&1; then
        echo "check.sh: fsck missed a fractional-container $section flip" >&2
        exit 1
    fi
done
FRAC_SB=$("$BIN" serve-bench "$FRAC" --threads 4 --requests 64)
echo "$FRAC_SB"
echo "$FRAC_SB" | grep -q 'hit rate' || {
    echo "check.sh: serve-bench over the v3 store reported no cache stats" >&2
    exit 1
}

echo "== serve-bench fault smoke (transient EIO + payload flips) =="
# the server must degrade gracefully under injected faults: transient
# reads retry, corrupt tensors quarantine, clean tensors keep serving,
# and the resilience counters are reported
SB_OUT=$("$BIN" serve-bench "$CLEAN" --threads 4 --requests 64 \
    --fault-eio-rate 0.05 --fault-flips 2)
echo "$SB_OUT"
echo "$SB_OUT" | grep -q 'resilience:' || {
    echo "check.sh: faulty serve-bench reported no resilience stats" >&2
    exit 1
}

echo "== serve-bench overload gate (1 permit, depth 2, 50ms deadline) =="
# saturate a tiny admission pipe under injected transient faults: every
# request must resolve typed (served, shed, queue-full or deadline) —
# the run terminates, the stats partition closes, and the exit is 0.
# The wall-clock timeout is the no-unbounded-wait backstop: if any wait
# in the serving layer were untimed, a stalled permit would hang the
# loadgen past it.
OVERLOAD_CMD=("$BIN" serve-bench "$CLEAN" --threads 8 --requests 128 \
    --max-decodes 1 --queue-depth 2 --deadline-ms 50 \
    --fault-eio-rate 0.05)
if command -v timeout > /dev/null 2>&1; then
    OV_OUT=$(timeout -k 10 120 "${OVERLOAD_CMD[@]}")
else
    echo "check.sh: WARNING: no 'timeout' binary; overload gate runs unwrapped" >&2
    OV_OUT=$("${OVERLOAD_CMD[@]}")
fi
echo "$OV_OUT"
echo "$OV_OUT" | grep -q 'partition: closed' || {
    echo "check.sh: overloaded serve-bench left its stats partition open" >&2
    exit 1
}

echo "== forced-ISA parity gate (scalar vs detected) =="
# the dispatch surface: report what this host runs, then prove the SIMD
# and scalar paths interchangeable at the container level.  Packing is
# deterministic, and every SIMD kernel is bit-exact with its scalar
# oracle, so the same spec/seed/lanes must produce byte-identical
# containers under OWF_ISA=scalar and under auto-detection — and each
# container must verify (checksums + bit-exact recon) when *decoded*
# under the opposite ISA.  On scalar-only hosts both runs select the
# scalar path and the gate degenerates to a determinism check, which
# must still pass.
"$BIN" isa
OWF_ISA=scalar "$BIN" pack \
    --spec 'cbrt-t5@4:block64-absmax:sparse0.01,compress' \
    --sim 96x64,4096 --seed 7 --codec rans --lanes 4 \
    --out "$PACK_DIR/isa_scalar.owq"
"$BIN" pack \
    --spec 'cbrt-t5@4:block64-absmax:sparse0.01,compress' \
    --sim 96x64,4096 --seed 7 --codec rans --lanes 4 \
    --out "$PACK_DIR/isa_auto.owq"
cmp "$PACK_DIR/isa_scalar.owq" "$PACK_DIR/isa_auto.owq" || {
    echo "check.sh: SIMD and scalar encodes produced different bytes" >&2
    exit 1
}
# cross-decode: scalar-packed verified on the detected ISA, and
# vice versa (inspect --verify re-derives the source and compares the
# decode to the last bit)
"$BIN" inspect "$PACK_DIR/isa_scalar.owq" --verify
OWF_ISA=scalar "$BIN" inspect "$PACK_DIR/isa_auto.owq" --verify
# the bench equivalence gates again, pinned to the scalar oracle: the
# [simd]/[scalar] parity asserts in benches/formats.rs must also hold
# when the active ISA is forced scalar (trivially — both sides then run
# the oracle), proving the forced override reaches the kernels
OWF_ISA=scalar OWF_BENCH_N=$((1 << 14)) OWF_THREADS=4 \
    cargo bench --bench formats > /dev/null

GRID='cbrt-t5@{3..6}:block{32,64,128}-absmax'   # 4 x 3 = 12 points
OUT="$(mktemp -d)/smoke_sweep.jsonl"

echo "== owf sweep smoke (12 points) =="
"$BIN" sweep "$GRID" --samples 4096 --out "$OUT"
ROWS=$(wc -l < "$OUT")
if [ "$ROWS" -ne 12 ]; then
    echo "check.sh: expected 12 rows after the first sweep, got $ROWS" >&2
    exit 1
fi

echo "== owf sweep --resume (must re-run zero points, twice) =="
for pass in 1 2; do
    "$BIN" sweep "$GRID" --samples 4096 --out "$OUT" --resume \
        | tee /dev/stderr | grep -q ' 0 ran,' || {
        echo "check.sh: resume pass $pass re-ran points" >&2
        exit 1
    }
done
ROWS=$(wc -l < "$OUT")
if [ "$ROWS" -ne 12 ]; then
    echo "check.sh: resume changed the row count to $ROWS" >&2
    exit 1
fi

echo "check.sh: OK"
