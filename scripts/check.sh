#!/usr/bin/env bash
# CI entry point: tier-1 build + tests, a bench smoke run at tiny n (which
# gates the LUT-vs-reference quantisation equivalence contract AND the
# decode_into-vs-decode_ref bit-exactness contract before any timing),
# an `owf pack`/unpack bit-exactness gate at tiny n (packed OWQ1 decode
# must be bit-identical to the in-memory pipeline, for both entropy
# codecs), then an `owf sweep` smoke run over a 12-point grid with
# --resume exercised twice (the second resume must re-run zero points and
# leave the row count unchanged).
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q (OWF_THREADS=4) =="
OWF_THREADS=4 cargo test -q

echo "== bench smoke: LUT/reference + decode bit-exactness gates (n=2^14) =="
# benches/formats.rs asserts bit-exact LUT/reference agreement for every
# benched codebook AND decode_into/decode_ref parity for every benched
# encoding before timing; at 2^14 elements this is a fast gate
OWF_BENCH_N=$((1 << 14)) OWF_THREADS=4 cargo bench --bench formats \
    > /dev/null

BIN=target/release/owf

echo "== owf pack/unpack bit-exactness gate (tiny n, huffman + rans) =="
# pack deterministic synthetic tensors, then prove the packed decode
# bit-identical to the in-memory pipeline (inspect --verify regenerates
# the sim source from the manifest seed and compares recon/sq-err/bits
# to the last bit), and that the concurrent server reports cache stats
PACK_DIR="$(mktemp -d)"
for codec in huffman rans; do
    OWQ="$PACK_DIR/gate_$codec.owq"
    "$BIN" pack --spec 'cbrt-t5@4:block64-absmax:sparse0.01,compress' \
        --sim 96x64,4096 --seed 7 --codec "$codec" --lanes 4 \
        --alloc variable --out "$OWQ"
    "$BIN" inspect "$OWQ" --verify
    SERVE_OUT=$("$BIN" serve-bench "$OWQ" --threads 4 --requests 64)
    echo "$SERVE_OUT"
    echo "$SERVE_OUT" | grep -q 'hit rate' || {
        echo "check.sh: serve-bench ($codec) reported no cache stats" >&2
        exit 1
    }
done

GRID='cbrt-t5@{3..6}:block{32,64,128}-absmax'   # 4 x 3 = 12 points
OUT="$(mktemp -d)/smoke_sweep.jsonl"

echo "== owf sweep smoke (12 points) =="
"$BIN" sweep "$GRID" --samples 4096 --out "$OUT"
ROWS=$(wc -l < "$OUT")
if [ "$ROWS" -ne 12 ]; then
    echo "check.sh: expected 12 rows after the first sweep, got $ROWS" >&2
    exit 1
fi

echo "== owf sweep --resume (must re-run zero points, twice) =="
for pass in 1 2; do
    "$BIN" sweep "$GRID" --samples 4096 --out "$OUT" --resume \
        | tee /dev/stderr | grep -q ' 0 ran,' || {
        echo "check.sh: resume pass $pass re-ran points" >&2
        exit 1
    }
done
ROWS=$(wc -l < "$OUT")
if [ "$ROWS" -ne 12 ]; then
    echo "check.sh: resume changed the row count to $ROWS" >&2
    exit 1
fi

echo "check.sh: OK"
