#!/usr/bin/env bash
# Perf trajectory recorder: run benches/formats.rs + benches/pipeline.rs
# and write machine-readable BENCH_formats.json / BENCH_pipeline.json
# (Melem/s per scheme) at the repo root.  Every perf PR diffs its numbers
# against the committed files from the previous PR, then commits the fresh
# ones as the next trajectory point.
#
# Usage: scripts/bench.sh [quick]
#   quick — 2^20 elements instead of 2^22 (for smoke runs)
set -euo pipefail

cd "$(dirname "$0")/.."
ROOT=$(pwd)
cd rust

# A trajectory point without a toolchain is not a trajectory point:
# refuse loudly instead of silently writing nothing.
if ! command -v cargo >/dev/null 2>&1; then
    echo "bench.sh: FATAL: cargo not found on PATH — cannot record a" >&2
    echo "bench.sh: trajectory point.  Install a Rust toolchain and" >&2
    echo "bench.sh: re-run; no BENCH_*.json was written." >&2
    exit 1
fi

N=$((1 << 22))
if [ "${1:-}" = "quick" ]; then
    N=$((1 << 20))
fi

echo "== cargo build --release --benches =="
cargo build --release --benches

echo "== benches/formats.rs (n=$N) -> BENCH_formats.json =="
OWF_BENCH_N=$N OWF_BENCH_JSON="$ROOT/BENCH_formats.json" \
    cargo bench --bench formats

echo "== benches/pipeline.rs (decode + pack/unpack rows at n=$N) -> BENCH_pipeline.json =="
OWF_BENCH_N=$N OWF_BENCH_JSON="$ROOT/BENCH_pipeline.json" \
    cargo bench --bench pipeline

echo "== benches/compression.rs -> BENCH_compression.json =="
OWF_BENCH_JSON="$ROOT/BENCH_compression.json" \
    cargo bench --bench compression

echo "bench.sh: wrote $ROOT/BENCH_formats.json, $ROOT/BENCH_pipeline.json and $ROOT/BENCH_compression.json"
