#!/usr/bin/env bash
# Perf trajectory recorder: run benches/formats.rs + benches/pipeline.rs
# and write machine-readable BENCH_formats.json / BENCH_pipeline.json
# (Melem/s per scheme) at the repo root.  Every perf PR diffs its numbers
# against the committed files from the previous PR, then commits the fresh
# ones as the next trajectory point.
#
# Usage: scripts/bench.sh [quick]
#   quick — 2^20 elements instead of 2^22 (for smoke runs)
set -euo pipefail

cd "$(dirname "$0")/.."
ROOT=$(pwd)
cd rust

N=$((1 << 22))
if [ "${1:-}" = "quick" ]; then
    N=$((1 << 20))
fi

echo "== cargo build --release --benches =="
cargo build --release --benches

echo "== benches/formats.rs (n=$N) -> BENCH_formats.json =="
OWF_BENCH_N=$N OWF_BENCH_JSON="$ROOT/BENCH_formats.json" \
    cargo bench --bench formats

echo "== benches/pipeline.rs -> BENCH_pipeline.json =="
OWF_BENCH_JSON="$ROOT/BENCH_pipeline.json" \
    cargo bench --bench pipeline

echo "bench.sh: wrote $ROOT/BENCH_formats.json and $ROOT/BENCH_pipeline.json"
