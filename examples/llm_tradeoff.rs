//! End-to-end driver (the repo's E2E validation workload): load the trained
//! microllama checkpoint, direct-cast it across the paper's headline format
//! families and bit widths, compute top-k KL against the reference model
//! through the PJRT runtime, and print the fig.-1 trade-off table with
//! throughput numbers. Results are appended to results/llm_tradeoff.jsonl.
//!
//! Requires `make artifacts`.
//!
//! ```bash
//! cargo run --release --offline --example llm_tradeoff \
//!     [--size m] [--eval-seqs 24]
//! ```

use std::time::Instant;

use owf::coordinator::config::Scheme;
use owf::coordinator::ResultSink;
use owf::eval::llm::{headline_schemes, Env};
use owf::eval::RunOpts;
use owf::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let mut opts = RunOpts::default();
    if let Some(i) = args.iter().position(|a| a == "--size") {
        opts.size = args[i + 1].clone();
    }
    if let Some(i) = args.iter().position(|a| a == "--eval-seqs") {
        opts.eval_seqs = args[i + 1].parse()?;
    }
    let size = opts.size.clone();
    let eval_seqs = opts.eval_seqs;
    let mut env = Env::open(opts)?;
    let n_params = env.checkpoint(&size)?.config.n_params;
    let seq_len = env.checkpoint(&size)?.config.seq_len;
    println!(
        "microllama-{size}: {n_params} params; eval {eval_seqs} seqs x {seq_len} tokens\n"
    );

    let sink = ResultSink::open("results/llm_tradeoff.jsonl")?;
    println!(
        "{:<26} {:>6} {:>10} {:>10} {:>9} {:>8} {:>9}",
        "format", "b", "KL", "±2se", "ΔCE", "R", "sec"
    );
    let t_all = Instant::now();
    let mut evals = 0usize;
    for b in [3u32, 4, 5] {
        for (label, spec) in headline_schemes(b) {
            let scheme = Scheme::parse(&spec)?;
            let t0 = Instant::now();
            let p = env.direct_cast(&size, &scheme, None, false)?;
            let dt = t0.elapsed().as_secs_f64();
            evals += 1;
            println!(
                "{:<26} {:>6.3} {:>10.5} {:>10.5} {:>9.5} {:>8.4} {:>9.2}",
                label,
                p.bits,
                p.kl.mean,
                2.0 * p.kl.sem,
                p.delta_ce,
                p.r,
                dt
            );
            sink.append(
                &Json::obj()
                    .push("example", "llm_tradeoff")
                    .push("model", size.as_str())
                    .push("format", label.as_str())
                    .push("spec", spec.as_str())
                    .push("bits", p.bits)
                    .push("kl", p.kl.mean)
                    .push("kl_2se", 2.0 * p.kl.sem)
                    .push("delta_ce", p.delta_ce)
                    .push("r", p.r)
                    .push("seconds", dt),
            )?;
        }
    }
    let total = t_all.elapsed().as_secs_f64();
    let tokens = (evals * eval_seqs * seq_len) as f64;
    println!(
        "\n{} evaluations in {:.1}s  ({:.0} quantise+eval tokens/s end-to-end)",
        evals,
        total,
        tokens / total
    );
    println!("rows appended to results/llm_tradeoff.jsonl");
    Ok(())
}
