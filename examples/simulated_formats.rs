//! Reproduce the paper's simulated-data headline (fig. 4): block-absmax
//! formats beat tensor-RMS optimal quantisers on iid data — *until* lossless
//! compression enters, revealing both as variable-length codes. Also prints
//! the fig. 16 cube-root-rule comparison and the fig. 22 α sweep.
//!
//! ```bash
//! cargo run --release --offline --example simulated_formats [--samples N]
//! ```

use owf::eval::{sim, RunOpts};

fn main() -> anyhow::Result<()> {
    let mut opts = RunOpts::default();
    let args: Vec<String> = std::env::args().collect();
    if let Some(i) = args.iter().position(|a| a == "--samples") {
        opts.samples = args[i + 1].parse()?;
    }
    for rep in [
        sim::fig4_sim_tradeoff(&opts)?,
        sim::fig16_cbrt_rule(&opts)?,
        sim::fig22_alpha(&opts)?,
    ] {
        println!("{}", rep.render());
    }
    Ok(())
}
