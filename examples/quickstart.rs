//! Quickstart: quantise one tensor with six formats and compare.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```
//!
//! No artifacts needed — this exercises the pure-Rust format framework on
//! synthetic heavy-tailed data (the shape real LLM weights have, fig. 25).

use owf::coordinator::config::Scheme;
use owf::dist::{Dist, Family};
use owf::eval::pipeline::qdq_tensor;
use owf::util::rng::Rng;
use owf::util::stats::relative_rms_error;

fn main() -> anyhow::Result<()> {
    // a synthetic "weight matrix": iid Student-t(5), the family that best
    // matches trained-LLM weight statistics
    let (rows, cols) = (512, 512);
    let mut rng = Rng::new(42);
    let data = Dist::standard(Family::StudentT, 5.0)
        .sample_vec(&mut rng, rows * cols);

    println!("quantising a {rows}x{cols} Student-t(5) tensor:\n");
    println!("{:<42} {:>7} {:>9} {:>9}", "scheme", "bits", "R", "R·2^b");
    for spec in [
        // naive 4-bit integer, one scale for the whole tensor
        "int@4:tensor-absmax",
        // the paper's √[3]p Student-t element format, RMS scaling
        "cbrt-t5@4:tensor-rms",
        // + block scaling: the variable-length trick (§2.1)
        "cbrt-t5@4:block128-absmax",
        // + signmax scaling (the paper's novel variant)
        "cbrt-t5@4:block128-signmax",
        // sparse outliers instead of blocks (SpQR-style)
        "cbrt-t5@4:tensor-rms:sparse0.001",
        // the §2.3 optimum: uniform grid + ideal entropy coding
        "grid@4:tensor-rms:compress",
    ] {
        let scheme = Scheme::parse(spec)?;
        let out = qdq_tensor(&scheme, &data, &[rows, cols], Some(1), &[], 1)?;
        let r = relative_rms_error(&data, &out.recon);
        println!(
            "{:<42} {:>7.3} {:>9.5} {:>9.4}",
            spec,
            out.bits,
            r,
            r * 2f64.powf(out.bits)
        );
    }
    println!(
        "\nLower R·2^b is better; see `owf report sim` for the full sweep."
    );
    Ok(())
}
