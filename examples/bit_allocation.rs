//! Fisher-based variable bit allocation (eq. 5 / figs. 6, 17): estimate the
//! Fisher diagonal through the PJRT runtime, derive per-tensor bit widths,
//! and verify the predicted-KL improvement over flat allocation.
//!
//! Requires `make artifacts`.
//!
//! ```bash
//! cargo run --release --offline --example bit_allocation [--size s|m]
//! ```

use owf::alloc::{
    flat_allocation, predicted_kl, round_allocation, variable_allocation,
};
use owf::eval::llm::Env;
use owf::eval::RunOpts;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let mut opts = RunOpts::default();
    if let Some(i) = args.iter().position(|a| a == "--size") {
        opts.size = args[i + 1].clone();
    }
    let size = opts.size.clone();
    let mut env = Env::open(opts)?;

    let infos = env.tensor_infos(&size)?;
    let target = 4.0;
    let alloc = variable_allocation(&infos, target);
    let rounded = round_allocation(&infos, &alloc, target);
    let flat = flat_allocation(&infos, target);

    println!("per-tensor allocation (target {target} bits/param), microllama-{size}:\n");
    println!("{:<44} {:>9} {:>10} {:>11} {:>6} {:>4}", "tensor",
             "numel", "rms", "fisher", "b*", "int");
    for ((t, &b), &bi) in infos.iter().zip(&alloc.bits).zip(&rounded.bits) {
        println!(
            "{:<44} {:>9} {:>10.4} {:>11.3e} {:>6.2} {:>4}",
            t.name, t.numel, t.rms, t.fisher_mean, b, bi as i64
        );
    }
    println!("\naverage bits: variable {:.4}, rounded {:.4}", alloc.average,
             rounded.average);
    println!(
        "predicted KL (eq. 3 + Zador): flat {:.4e}, variable {:.4e}  ({:.2}x better)",
        predicted_kl(&infos, &flat),
        predicted_kl(&infos, &alloc),
        predicted_kl(&infos, &flat) / predicted_kl(&infos, &alloc)
    );
    println!("\nmeasured end-to-end comparison: `owf report fig6`");
    Ok(())
}
